//! Sharded serve scale-out: N scheduler shards on `std::thread` workers,
//! key-affinity routing, whole-queue work stealing, and zero-downtime
//! model swap.
//!
//! The single-threaded [`crate::serve::Router`] caps aggregate throughput
//! at one drain loop no matter how many cores the box has. SHINE's
//! serving contract makes sharding natural: the only cross-request state
//! is the per-[`ModelKey`] calibration estimate (the forward-pass
//! quasi-Newton inverse reused for the backward sweep), so routing every
//! request of a key to one shard keeps that estimate **thread-local — it
//! never crosses threads** and the hot path takes no lock while solving.
//!
//! # Threading model
//!
//! [`ShardedRouter::new`] spawns `shards` worker threads (pure
//! `std::thread` + `Mutex`/`Condvar`, consistent with the vendored-deps
//! idiom). Each worker owns, privately on its stack:
//!
//! * a [`KeyedScheduler`] of queued requests (behind the shard's mutex so
//!   the front door can push),
//! * a map `ModelKey → ServeEngine` built and calibrated **inside** the
//!   worker thread — engines (and the solver trait objects within) are
//!   never sent across threads.
//!
//! Shared state is two layers, with one global lock-order rule — **the
//! registry mutex is always acquired before any shard mutex, never the
//! reverse** — which makes every multi-lock path (submit, steal, swap
//! cutover) deadlock-free by construction:
//!
//! * the **registry**: every registered key's model handle, its current
//!   owning shard, and the `model id → live version` routing table;
//! * per shard, a mutex-guarded [`KeyedScheduler`] + control queue +
//!   published [`ShardStats`].
//!
//! # Work stealing
//!
//! A shard with nothing releasable probes the others (registry lock held
//! throughout, so concurrent steals are serialized) for a key whose batch
//! is *ready* but not yet picked up — the backlogged-victim signal. It
//! then moves that key's **entire queue** ([`KeyedScheduler::take_queue`]
//! / [`KeyedScheduler::inject_queue`]) and re-homes the key in the
//! registry in the same critical section, so subsequent arrivals follow
//! the queue. Stealing whole queues rather than items is what preserves
//! FIFO-within-key: at any instant a key's queue lives in exactly one
//! scheduler, and admission stamps (drawn from a global counter while the
//! owning shard's lock is held) stay monotone in submission order. The
//! thief calibrates its own engine for the stolen key from the same
//! deterministic z₀ = 0 probe, so its estimate is bit-identical to the
//! home shard's — stealing moves work, never estimates.
//!
//! **Steal hysteresis:** a freshly stolen key enters a *cooldown* of
//! [`STEAL_COOLDOWN_BATCHES`] served batches during which it cannot be
//! stolen again. Without it, alternating load makes ownership ping-pong
//! between shards — each bounce re-homes the queue and makes every
//! first-time owner pay a calibration probe. The cooldown is counted in
//! batches the new owner actually serves (not wall-clock, not steal
//! probes), so a spinning idle shard cannot burn through it; fresh keys
//! start with no cooldown, so the *first* steal of a backlogged key is
//! never delayed.
//!
//! # Zero-downtime swap (blue/green)
//!
//! [`ShardedRouter::swap`] registers the new parameter version as
//! *calibrating* on its affinity shard (the hash mixes the version, so a
//! roll usually lands on a different — "background" — shard) while the
//! old version keeps serving. When the background calibration finishes,
//! the worker performs the **atomic cutover** under the registry lock:
//! the model's live version bumps, and exactly the old key is marked
//! retired. Requests queued before the cutover still serve on the old
//! engine; once its queue drains, the owning shard garbage-collects the
//! retired entry and drops the old engine — exactly one key's estimate is
//! invalidated, every other key's survives bit-identically.
//!
//! # Determinism
//!
//! Sharded results are **bit-identical per request** to the single-shard
//! router: batched solves are bit-identical per column to solo solves
//! regardless of batch composition (pinned by `rust/tests/serve_batch.rs`),
//! calibration from z₀ = 0 is deterministic, and the backward sweep is a
//! deterministic panel apply — so neither shard count, batch formation,
//! nor steal timing can perturb a trajectory (pinned by
//! `rust/tests/serve_shard.rs`).

use crate::linalg::vecops::Elem;
use crate::serve::engine::{BreakerState, EngineConfig, ServeEngine};
use crate::serve::router::{BatchResidual, KeyedScheduler, ModelKey};
use crate::serve::scheduler::{ConfigError, RetryPolicy, SchedulerConfig};
use crate::solvers::fixed_point::ColStats;
use crate::util::threads;
use crate::util::timer::Stopwatch;
use std::collections::VecDeque;
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::Duration;

/// Lock a mutex, recovering from poison: a panicking worker must not make
/// the shared state permanently unreachable (supervision recovers the
/// in-flight casualties explicitly; the data under the lock is always left
/// structurally valid because panics can only originate in model residual
/// evaluations, never mid-mutation of scheduler or registry state).
fn lock_ok<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// A model shared with the shard workers. `Send + Sync` because several
/// shards may evaluate the residual concurrently (the model is immutable
/// parameter state; all mutable solve state is engine-local).
pub type SharedModel<E> = Arc<dyn BatchResidual<E> + Send + Sync>;

/// Typed per-request failure: every submitted request resolves to exactly
/// one outcome — a success ([`ShardResponse::error`] `None`) or one of
/// these. Nothing is silently dropped and `collect` never hangs on a
/// casualty.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ServeError {
    /// Bounced at admission: the owning shard's queue is at `queue_cap`.
    /// Retry after the hint (seconds, from the queue's recent drain rate).
    QueueFull { retry_after: f64 },
    /// The request's deadline passed before (or while) it was served.
    DeadlineExceeded,
    /// The forward solve retired without reaching tolerance.
    Unconverged,
    /// The model emitted non-finite values for this request (NaN/Inf in
    /// the fixed point, the backward answer, or the final residual).
    ModelFault,
    /// The worker serving this request's batch died; supervision respawned
    /// the shard and reports the in-flight batch as casualties.
    WorkerLost,
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            ServeError::QueueFull { retry_after } => {
                write!(f, "queue full (retry after {retry_after:.3e}s)")
            }
            ServeError::DeadlineExceeded => write!(f, "deadline exceeded"),
            ServeError::Unconverged => write!(f, "forward solve did not converge"),
            ServeError::ModelFault => write!(f, "model emitted non-finite values"),
            ServeError::WorkerLost => write!(f, "shard worker died mid-batch"),
        }
    }
}

/// Idle-shard poll cadence: how often an idle worker re-probes for steal
/// opportunities and deadline releases (with exponential backoff to
/// [`STEAL_POLL_MAX_S`] while nothing arrives).
const STEAL_POLL_S: f64 = 200e-6;
const STEAL_POLL_MAX_S: f64 = 5e-3;

/// Steal hysteresis: batches the new owner must serve for a stolen key
/// before another shard may steal it again (see the module docs). Counted
/// in served batches of that key, so the cooldown reflects actual serving
/// progress rather than wall-clock or probe cadence.
pub const STEAL_COOLDOWN_BATCHES: u32 = 4;

/// Configuration of a [`ShardedRouter`]: shard count plus the per-key
/// engine config (shared by every engine, as in [`crate::serve::Router`])
/// and the per-shard scheduler config.
#[derive(Clone, Copy, Debug)]
pub struct ShardConfig {
    /// Worker threads (= scheduler shards). One shard reproduces the
    /// single-threaded router exactly.
    pub shards: usize,
    /// Built per key, inside the owning worker thread.
    pub engine: EngineConfig,
    /// Per-shard admission queue (each shard holds its own `queue_cap`).
    pub sched: SchedulerConfig,
    /// Whole-queue work stealing (on by default; off pins every key to its
    /// affinity shard, useful when debugging placement).
    pub steal: bool,
    /// Per-key respawn cap: after this many worker panics attributable to
    /// one key (its batch or its calibration probe was executing), the key
    /// is **quarantined** — queued and future requests resolve as typed
    /// [`ServeError::ModelFault`] instead of respawn-looping the shard
    /// (the known limit in `docs/adr/004`). `0` disables the cap.
    pub quarantine_after: u32,
}

/// Default [`ShardConfig::quarantine_after`]: strikes before a key whose
/// model keeps panicking is quarantined.
pub const QUARANTINE_STRIKES: u32 = 3;

impl ShardConfig {
    pub fn new(shards: usize, engine: EngineConfig, sched: SchedulerConfig) -> ShardConfig {
        ShardConfig {
            shards,
            engine,
            sched,
            steal: true,
            quarantine_after: QUARANTINE_STRIKES,
        }
    }
}

/// One request through the sharded front door. `z0` is the warm-start
/// iterate (the serving convention is zeros) and `cotangent` the SHINE
/// backward right-hand side; both must be the target model's dimension.
#[derive(Clone, Debug)]
pub struct ShardRequest<E: Elem> {
    /// Caller-side request id, echoed in the response.
    pub id: usize,
    pub z0: Vec<E>,
    pub cotangent: Vec<E>,
    /// Absolute deadline on the router clock ([`ShardedRouter::now`]);
    /// `None` never expires. Enforced at admission (an already-expired
    /// request bounces as [`SubmitError::DeadlineExceeded`]) and at drain
    /// time (a queued request whose deadline passes resolves as a typed
    /// [`ServeError::DeadlineExceeded`] instead of being served).
    pub deadline: Option<f64>,
}

impl<E: Elem> ShardRequest<E> {
    /// A request with no deadline (the common case).
    pub fn new(id: usize, z0: Vec<E>, cotangent: Vec<E>) -> ShardRequest<E> {
        ShardRequest {
            id,
            z0,
            cotangent,
            deadline: None,
        }
    }
}

/// One completed request.
#[derive(Clone, Debug)]
pub struct ShardResponse<E: Elem> {
    /// Caller-side request id from the matching [`ShardRequest`].
    pub id: usize,
    /// The model snapshot that served this request (reveals which side of
    /// a version cutover it landed on).
    pub key: ModelKey,
    /// Shard whose engine served it.
    pub shard: usize,
    /// Global admission stamp, assigned in drain order under the owning
    /// shard's lock — within a key, sorting by `seq` recovers submission
    /// order even across steals (the FIFO-within-key witness).
    pub seq: u64,
    /// Fixed point.
    pub z: Vec<E>,
    /// SHINE backward answer for the cotangent.
    pub w: Vec<E>,
    /// Per-column forward telemetry.
    pub stats: ColStats,
    /// Router-clock seconds at admission / completion (latency =
    /// `completed - enqueued`).
    pub enqueued: f64,
    pub completed: f64,
    /// `None` on success; a typed failure otherwise (`z`/`w` are empty for
    /// [`ServeError::DeadlineExceeded`] and [`ServeError::WorkerLost`],
    /// best-effort values for [`ServeError::Unconverged`] and
    /// [`ServeError::ModelFault`]).
    pub error: Option<ServeError>,
}

impl<E: Elem> ShardResponse<E> {
    /// Whether this request was served successfully.
    pub fn ok(&self) -> bool {
        self.error.is_none()
    }
}

/// Why [`ShardedRouter::submit`] bounced a request (the payload is handed
/// back, mirroring the scheduler's backpressure contract).
#[derive(Debug)]
pub enum SubmitError<E: Elem> {
    /// No live version is registered for the model id.
    UnknownModel(ShardRequest<E>),
    /// The owning shard's queue is at `queue_cap`; back off for
    /// `retry_after` seconds (the queue's recent-drain-rate hint) before
    /// retrying.
    QueueFull {
        req: ShardRequest<E>,
        retry_after: f64,
    },
    /// The request's deadline had already passed at admission.
    DeadlineExceeded(ShardRequest<E>),
    /// The live version of this model is quarantined: its respawn strikes
    /// crossed [`ShardConfig::quarantine_after`], so it can never serve
    /// again (resolve as [`ServeError::ModelFault`]).
    Quarantined(ShardRequest<E>),
}

impl<E: Elem> SubmitError<E> {
    /// Recover the rejected request.
    pub fn into_request(self) -> ShardRequest<E> {
        match self {
            SubmitError::UnknownModel(r)
            | SubmitError::QueueFull { req: r, .. }
            | SubmitError::DeadlineExceeded(r)
            | SubmitError::Quarantined(r) => r,
        }
    }

    /// The matching typed outcome (what a driver records for a shed
    /// request).
    pub fn as_serve_error(&self) -> ServeError {
        match self {
            SubmitError::UnknownModel(_) => ServeError::ModelFault,
            SubmitError::QueueFull { retry_after, .. } => ServeError::QueueFull {
                retry_after: *retry_after,
            },
            SubmitError::DeadlineExceeded(_) => ServeError::DeadlineExceeded,
            SubmitError::Quarantined(_) => ServeError::ModelFault,
        }
    }
}

/// Published per-shard counters (snapshot via [`ShardedRouter::shard_stats`]).
#[derive(Clone, Debug, Default)]
pub struct ShardStats {
    /// Requests served (responses produced) by this shard.
    pub served: usize,
    /// Batches dispatched.
    pub batches: usize,
    /// Whole-queue steals performed *by* this shard (as the thief).
    pub steals: usize,
    /// Engines built + calibrated on this shard (registration, swap
    /// calibration, or first batch after a steal).
    pub calibrations: usize,
    /// Stale-estimate re-calibrations triggered by the trip-rate policy.
    pub recalibrations: usize,
    /// Times this shard's worker died and was respawned by supervision.
    pub respawns: usize,
    /// In-flight requests reported as [`ServeError::WorkerLost`] across
    /// this shard's respawns.
    pub worker_lost: usize,
    /// Queued requests that resolved as [`ServeError::DeadlineExceeded`]
    /// at drain time.
    pub deadline_expired: usize,
    /// Queued requests resolved as [`ServeError::ModelFault`] because
    /// their key was quarantined (the solve never ran).
    pub quarantined: usize,
    /// Engines on this shard whose circuit breaker is currently open
    /// (serving degraded Jacobian-free backwards).
    pub open_breakers: usize,
    /// Keys whose engine (and calibration estimate) currently live on this
    /// shard — the observable for "a swap invalidates exactly one key".
    pub engine_keys: Vec<ModelKey>,
}

/// Per-[`ModelKey`] serving telemetry, merged across shards by
/// [`ShardedRouter::key_metrics`] — the `/metrics` observability surface:
/// [`BatchReport`](crate::serve::BatchReport) aggregates, the §3
/// fallback-guard trip rate, calibration staleness, breaker state, and the
/// quarantine record. Counters are summed across every shard that ever
/// served the key; gauges (`fallback_rate`, `estimate_stale`, `breaker`)
/// are taken from the key's current owning shard when it has served the
/// key, best-effort otherwise.
#[derive(Clone, Debug)]
pub struct KeyMetrics {
    pub key: ModelKey,
    /// Responses produced for this key (success or typed failure).
    pub served: usize,
    /// Batches dispatched for this key.
    pub batches: usize,
    /// Total forward iterations across served columns
    /// ([`BatchReport::fwd_col_iters_total`](crate::serve::BatchReport)).
    pub fwd_iters: usize,
    /// Columns the §3 guard reverted to the Jacobian-free direction.
    pub fallback_cols: usize,
    /// Columns whose residual/cotangent answer was non-finite.
    pub nonfinite_cols: usize,
    /// Columns retired without reaching tolerance
    /// ([`ServeError::Unconverged`]).
    pub unconverged: usize,
    /// Responses typed [`ServeError::ModelFault`] (non-finite columns plus
    /// quarantine drains).
    pub model_faults: usize,
    /// Guard trip rate since the estimate's last calibration — the
    /// staleness signal driving
    /// [`RecalibPolicy`](crate::serve::RecalibPolicy).
    pub fallback_rate: f64,
    /// Whether the estimate had crossed the staleness threshold as of the
    /// key's last served batch.
    pub estimate_stale: bool,
    /// Circuit-breaker state after the key's last served batch.
    pub breaker: BreakerState,
    /// Engines built + calibrated for this key (registration, swap, steal,
    /// respawn rebuilds).
    pub calibrations: usize,
    /// Stale-estimate re-calibrations.
    pub recalibrations: usize,
    /// Worker panics attributed to this key (its batch or calibration
    /// probe was executing when the shard died).
    pub strikes: u32,
    /// Whether the key crossed [`ShardConfig::quarantine_after`] and was
    /// quarantined.
    pub quarantined: bool,
}

impl KeyMetrics {
    fn new(key: ModelKey) -> KeyMetrics {
        KeyMetrics {
            key,
            served: 0,
            batches: 0,
            fwd_iters: 0,
            fallback_cols: 0,
            nonfinite_cols: 0,
            unconverged: 0,
            model_faults: 0,
            fallback_rate: 0.0,
            estimate_stale: false,
            breaker: BreakerState::Closed,
            calibrations: 0,
            recalibrations: 0,
            strikes: 0,
            quarantined: false,
        }
    }
}

/// Lifecycle of a registered key in the blue/green protocol.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum KeyState {
    /// Background calibration in progress; not yet routable.
    Calibrating,
    /// The live route for its model id (or a coexisting older version
    /// still draining — the live table is the routing authority).
    Live,
    /// Cut over from; serves only already-queued requests, then GC'd.
    Retired,
    /// Respawn strikes crossed [`ShardConfig::quarantine_after`]: never
    /// serves again; queued and future requests resolve as
    /// [`ServeError::ModelFault`]. Never GC'd (the record *is* the
    /// quarantine), never cut over to.
    Quarantined,
}

struct RegEntry<E: Elem> {
    key: ModelKey,
    model: SharedModel<E>,
    /// Shard currently owning this key's queue (affinity hash at
    /// registration; work stealing re-homes it).
    shard: usize,
    state: KeyState,
    /// Worker panics attributed to this key — the quarantine counter.
    strikes: u32,
    /// Batches the current owner must serve before this key may be stolen
    /// again — the steal-hysteresis counter, stamped to
    /// [`STEAL_COOLDOWN_BATCHES`] on every steal and decremented per served
    /// batch of the key. Fresh keys start at 0 (first steal never delayed).
    steal_cooldown: u32,
}

/// Global routing state: one entry per registered key plus the
/// `model id → live version` table. Guarded by `Shared::reg`; always
/// locked *before* any shard mutex.
struct Registry<E: Elem> {
    entries: Vec<RegEntry<E>>,
    live: Vec<(u32, u32)>,
}

impl<E: Elem> Registry<E> {
    fn find(&self, key: ModelKey) -> Option<&RegEntry<E>> {
        self.entries.iter().find(|e| e.key == key)
    }

    fn find_mut(&mut self, key: ModelKey) -> Option<&mut RegEntry<E>> {
        self.entries.iter_mut().find(|e| e.key == key)
    }

    fn live_version(&self, model: u32) -> Option<u32> {
        self.live
            .iter()
            .find(|(m, _)| *m == model)
            .map(|(_, v)| *v)
    }
}

/// A queued request (the scheduler payload).
struct QueuedReq<E: Elem> {
    id: usize,
    z0: Vec<E>,
    cot: Vec<E>,
}

/// What supervision needs to report one in-flight request as a
/// [`ServeError::WorkerLost`] casualty: recorded under the shard lock at
/// drain time, cleared after the batch's responses publish.
#[derive(Clone, Copy, Debug)]
struct InFlight {
    id: usize,
    seq: u64,
    enqueued: f64,
}

struct ShardState<E: Elem> {
    sched: KeyedScheduler<QueuedReq<E>>,
    /// Keys awaiting background calibration on this shard.
    ctl: VecDeque<ModelKey>,
    stats: ShardStats,
    /// Per-key telemetry for keys this shard has served (merged across
    /// shards by [`ShardedRouter::key_metrics`]).
    keys: Vec<KeyMetrics>,
    /// The batch currently being served (empty between batches). If the
    /// worker dies mid-batch, supervision publishes each entry as a
    /// [`ServeError::WorkerLost`] response so `collect` never hangs.
    inflight: Vec<InFlight>,
    inflight_key: Option<ModelKey>,
    /// Control op currently executing (re-queued on worker death so a
    /// pending registration is never lost).
    active_ctl: Option<ModelKey>,
}

impl<E: Elem> ShardState<E> {
    fn new(sched: SchedulerConfig) -> ShardState<E> {
        ShardState {
            sched: KeyedScheduler::new(sched),
            ctl: VecDeque::new(),
            stats: ShardStats::default(),
            keys: Vec::new(),
            inflight: Vec::new(),
            inflight_key: None,
            active_ctl: None,
        }
    }

    /// The shard-local metrics row for `key`, created on first touch.
    fn key_entry(&mut self, key: ModelKey) -> &mut KeyMetrics {
        if let Some(p) = self.keys.iter().position(|m| m.key == key) {
            return &mut self.keys[p];
        }
        self.keys.push(KeyMetrics::new(key));
        self.keys.last_mut().expect("just pushed")
    }
}

struct ShardCell<E: Elem> {
    state: Mutex<ShardState<E>>,
    cv: Condvar,
}

struct Shared<E: Elem> {
    cfg: ShardConfig,
    reg: Mutex<Registry<E>>,
    reg_cv: Condvar,
    cells: Vec<ShardCell<E>>,
    done: Mutex<Vec<ShardResponse<E>>>,
    done_cv: Condvar,
    /// Global admission-stamp counter (see [`ShardResponse::seq`]).
    seq: AtomicU64,
    /// The router clock: all arrival/completion instants are seconds since
    /// construction.
    clock: Stopwatch,
    shutdown: AtomicBool,
}

/// The sharded serving front door. See the module docs for the threading
/// model, lock order, and the stealing / swap protocols.
///
/// Carries the same optional panel-storage parameters as
/// [`crate::serve::Router`]: a `ShardedRouter<f32, Bf16, f32>` runs every
/// shard's per-key estimates in the mixed reduced-precision layout. The
/// parameters select the worker-local [`ServeEngine`] instantiation only —
/// queues, requests and responses stay in `E`.
pub struct ShardedRouter<E: Elem, EU: Elem = E, EV: Elem = EU> {
    sh: Arc<Shared<E>>,
    handles: Vec<JoinHandle<()>>,
    /// `threads::set_active_shards` value to restore on shutdown.
    prev_shards: usize,
    /// The panel-storage instantiation lives in the worker threads'
    /// engines, not in any shared field.
    _panel: std::marker::PhantomData<(EU, EV)>,
}

impl<E: Elem, EU: Elem, EV: Elem> ShardedRouter<E, EU, EV> {
    /// Build and spawn the sharded router, panicking on an invalid config
    /// (in-crate callers with static configs; CLI surfaces use
    /// [`ShardedRouter::try_new`]).
    pub fn new(cfg: ShardConfig) -> ShardedRouter<E, EU, EV> {
        match Self::try_new(cfg) {
            Ok(r) => r,
            Err(e) => panic!("invalid shard config: {e}"),
        }
    }

    /// Validating constructor: every config invariant is checked on the
    /// caller's thread and returned as a typed [`ConfigError`] — a mistake
    /// (e.g. a non-Broyden calibration spec) surfaces here instead of
    /// killing a worker mid-calibration.
    pub fn try_new(cfg: ShardConfig) -> Result<ShardedRouter<E, EU, EV>, ConfigError> {
        if cfg.shards == 0 {
            return Err(ConfigError::ZeroShards);
        }
        cfg.engine.validate()?;
        cfg.sched.validate()?;
        if cfg.sched.max_batch > cfg.engine.max_batch {
            return Err(ConfigError::SchedBatchExceedsEngine {
                sched_batch: cfg.sched.max_batch,
                engine_batch: cfg.engine.max_batch,
            });
        }
        // Divide the kernel-level thread fan-out across shards so N drain
        // loops cannot oversubscribe the cores (restored on shutdown).
        let prev_shards = threads::set_active_shards(cfg.shards);
        let cells = (0..cfg.shards)
            .map(|_| ShardCell {
                state: Mutex::new(ShardState::new(cfg.sched)),
                cv: Condvar::new(),
            })
            .collect();
        let sh = Arc::new(Shared {
            cfg,
            reg: Mutex::new(Registry {
                entries: Vec::new(),
                live: Vec::new(),
            }),
            reg_cv: Condvar::new(),
            cells,
            done: Mutex::new(Vec::new()),
            done_cv: Condvar::new(),
            seq: AtomicU64::new(0),
            clock: Stopwatch::start(),
            shutdown: AtomicBool::new(false),
        });
        let handles = (0..cfg.shards)
            .map(|i| {
                let sh = Arc::clone(&sh);
                std::thread::Builder::new()
                    .name(format!("shine-shard-{i}"))
                    .spawn(move || worker_loop::<E, EU, EV>(i, sh))
                    .expect("spawn shard worker")
            })
            .collect();
        Ok(ShardedRouter {
            sh,
            handles,
            prev_shards,
            _panel: std::marker::PhantomData,
        })
    }

    pub fn config(&self) -> &ShardConfig {
        &self.sh.cfg
    }

    /// Seconds since construction on the router clock — the time base for
    /// [`ShardRequest::deadline`].
    pub fn now(&self) -> f64 {
        self.sh.clock.elapsed()
    }

    /// The shard `key` hashes to (its home before any stealing).
    pub fn affinity(&self, key: ModelKey) -> usize {
        affinity_shard(key, self.sh.cfg.shards)
    }

    /// Register a model snapshot and **block** until its background
    /// calibration finishes and it is the live route for its model id.
    /// For a non-blocking roll of an already-live model, use
    /// [`ShardedRouter::swap`]. Returns `false` if the key was quarantined
    /// before going live (its calibration probe kept panicking) — the key
    /// will never serve.
    pub fn register(&self, key: ModelKey, model: SharedModel<E>) -> bool {
        self.swap(key, model);
        self.wait_live(key)
    }

    /// Zero-downtime version roll: enqueue `key` for background
    /// calibration on its affinity shard and return immediately. The
    /// previously live version keeps serving until the calibration
    /// completes, at which point the worker atomically cuts the live route
    /// over and retires exactly the old key (see the module docs). A stale
    /// replay (version ≤ current live) calibrates but never cuts over.
    pub fn swap(&self, key: ModelKey, model: SharedModel<E>) {
        let shard = affinity_shard(key, self.sh.cfg.shards);
        {
            let mut reg = lock_ok(&self.sh.reg);
            assert!(
                reg.find(key).is_none(),
                "key {key} is already registered"
            );
            reg.entries.push(RegEntry {
                key,
                model,
                shard,
                state: KeyState::Calibrating,
                steal_cooldown: 0,
                strikes: 0,
            });
        }
        let cell = &self.sh.cells[shard];
        let mut st = lock_ok(&cell.state);
        st.ctl.push_back(key);
        drop(st);
        cell.cv.notify_one();
    }

    /// Block until `key` is the live route for its model id (`true`), or
    /// until the key is quarantined and can never go live (`false`) — the
    /// wait would otherwise hang forever on a calibration panic loop.
    pub fn wait_live(&self, key: ModelKey) -> bool {
        let mut reg = lock_ok(&self.sh.reg);
        loop {
            if reg.live_version(key.model) == Some(key.version) {
                return true;
            }
            if matches!(
                reg.find(key).map(|e| e.state),
                Some(KeyState::Quarantined)
            ) {
                return false;
            }
            reg = self.sh.reg_cv.wait(reg).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// The live (routed-to) version of a model id, if any.
    pub fn live_version(&self, model: u32) -> Option<u32> {
        lock_ok(&self.sh.reg).live_version(model)
    }

    /// Registered keys (live, calibrating, and retired-but-draining).
    pub fn keys(&self) -> Vec<ModelKey> {
        let reg = lock_ok(&self.sh.reg);
        reg.entries.iter().map(|e| e.key).collect()
    }

    /// Route a request to the live version of `model` and enqueue it on
    /// the key's owning shard. Returns the [`ModelKey`] it was routed to —
    /// resolved atomically with the enqueue, so across a concurrent swap
    /// the submission order cleanly partitions into an old-key prefix and
    /// a new-key suffix.
    pub fn submit(&self, model: u32, req: ShardRequest<E>) -> Result<ModelKey, SubmitError<E>> {
        let now = self.sh.clock.elapsed();
        if let Some(dl) = req.deadline {
            if dl <= now {
                return Err(SubmitError::DeadlineExceeded(req));
            }
        }
        let reg = lock_ok(&self.sh.reg);
        let Some(version) = reg.live_version(model) else {
            return Err(SubmitError::UnknownModel(req));
        };
        let key = ModelKey::new(model, version);
        let entry = reg.find(key).expect("live key is registered");
        if entry.state == KeyState::Quarantined {
            return Err(SubmitError::Quarantined(req));
        }
        let shard = entry.shard;
        let cell = &self.sh.cells[shard];
        // Take the shard lock while still holding the registry lock
        // (registry → shard order): a steal re-homing this key cannot slip
        // between shard resolution and the push.
        let mut st = lock_ok(&cell.state);
        drop(reg);
        let deadline = req.deadline.unwrap_or(f64::INFINITY);
        let q = QueuedReq {
            id: req.id,
            z0: req.z0,
            cot: req.cotangent,
        };
        match st.sched.push_deadline(now, deadline, key, q) {
            Ok(()) => {
                drop(st);
                cell.cv.notify_one();
                Ok(key)
            }
            Err(rej) => {
                let q = rej.item;
                Err(SubmitError::QueueFull {
                    req: ShardRequest {
                        id: q.id,
                        z0: q.z0,
                        cotangent: q.cot,
                        deadline: req.deadline,
                    },
                    retry_after: rej.retry_after,
                })
            }
        }
    }

    /// [`ShardedRouter::submit`] under a bounded [`RetryPolicy`]:
    /// [`SubmitError::QueueFull`] rejections sleep the policy's backoff
    /// (derived from the queue's `retry_after` hint) and retry; every
    /// other outcome is final. Returns the result plus the number of
    /// retries performed — the value the HTTP surface echoes in its
    /// `x-shine-attempts` header. **Blocks** the calling thread while
    /// backing off.
    pub fn submit_with_retry(
        &self,
        model: u32,
        req: ShardRequest<E>,
        policy: &RetryPolicy,
    ) -> (Result<ModelKey, SubmitError<E>>, usize) {
        let mut req = req;
        let mut attempt = 0usize;
        loop {
            match self.submit(model, req) {
                Ok(key) => return (Ok(key), attempt),
                Err(SubmitError::QueueFull { req: r, retry_after }) => {
                    match policy.backoff(attempt, retry_after) {
                        Some(delay) => {
                            attempt += 1;
                            std::thread::sleep(Duration::from_secs_f64(delay));
                            req = r;
                        }
                        None => {
                            return (
                                Err(SubmitError::QueueFull { req: r, retry_after }),
                                attempt,
                            )
                        }
                    }
                }
                Err(e) => return (Err(e), attempt),
            }
        }
    }

    /// Drain whatever responses have completed (non-blocking).
    pub fn try_collect(&self) -> Vec<ShardResponse<E>> {
        let mut done = lock_ok(&self.sh.done);
        std::mem::take(&mut *done)
    }

    /// Block until at least `n` responses have accumulated, draining them.
    pub fn collect(&self, n: usize) -> Vec<ShardResponse<E>> {
        let mut out = Vec::with_capacity(n);
        let mut done = lock_ok(&self.sh.done);
        loop {
            out.append(&mut *done);
            if out.len() >= n {
                return out;
            }
            done = self.sh.done_cv.wait(done).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Like [`ShardedRouter::collect`], but wait at most `timeout_s`
    /// seconds: returns whatever has accumulated (possibly empty) once `n`
    /// responses are available or the timeout elapses — the wakeable wait
    /// a completion-forwarding thread (the HTTP gateway's collector) needs
    /// so shutdown is never stuck on an empty queue.
    pub fn collect_timeout(&self, n: usize, timeout_s: f64) -> Vec<ShardResponse<E>> {
        let deadline = self.sh.clock.elapsed() + timeout_s;
        let mut out = Vec::new();
        let mut done = lock_ok(&self.sh.done);
        loop {
            out.append(&mut *done);
            let left = deadline - self.sh.clock.elapsed();
            if out.len() >= n || left <= 0.0 {
                return out;
            }
            let (g, _) = self
                .sh
                .done_cv
                .wait_timeout(done, Duration::from_secs_f64(left))
                .unwrap_or_else(|p| p.into_inner());
            done = g;
        }
    }

    /// Requests queued (admitted, not yet drained) across all shards.
    pub fn pending(&self) -> usize {
        self.sh
            .cells
            .iter()
            .map(|c| lock_ok(&c.state).sched.len())
            .sum()
    }

    /// Snapshot every shard's published counters.
    pub fn shard_stats(&self) -> Vec<ShardStats> {
        self.sh
            .cells
            .iter()
            .map(|c| lock_ok(&c.state).stats.clone())
            .collect()
    }

    /// Whole-queue steals across all shards.
    pub fn total_steals(&self) -> usize {
        self.shard_stats().iter().map(|s| s.steals).sum()
    }

    /// Per-shard admitted-but-undrained queue depths.
    pub fn queue_depths(&self) -> Vec<usize> {
        self.sh
            .cells
            .iter()
            .map(|c| lock_ok(&c.state).sched.len())
            .collect()
    }

    /// Per-shard backpressure hints: the seconds a bounced caller should
    /// wait, from each queue's recent drain rate (what
    /// [`SubmitError::QueueFull`] would carry right now).
    pub fn retry_hints(&self) -> Vec<f64> {
        self.sh
            .cells
            .iter()
            .map(|c| lock_ok(&c.state).sched.retry_after())
            .collect()
    }

    /// Quarantined keys with their strike counts (the `/metrics` record of
    /// the per-key respawn cap).
    pub fn quarantined_keys(&self) -> Vec<(ModelKey, u32)> {
        let reg = lock_ok(&self.sh.reg);
        reg.entries
            .iter()
            .filter(|e| e.state == KeyState::Quarantined)
            .map(|e| (e.key, e.strikes))
            .collect()
    }

    /// Merge every shard's per-key telemetry into one row per
    /// [`ModelKey`], stamped with the registry's strike/quarantine record.
    /// Counters are summed; gauges come from the key's current owning
    /// shard when it has served the key (best-effort otherwise — a steal
    /// can leave the gauge one batch behind). Registered keys that never
    /// served (still calibrating, or quarantined before first batch) get a
    /// zero row so quarantine is visible the moment it happens.
    pub fn key_metrics(&self) -> Vec<KeyMetrics> {
        // Registry lock first, released before any shard lock (the global
        // order — even though we never hold both here, keep it one-way).
        let reg_info: Vec<(ModelKey, u32, bool, usize)> = {
            let reg = lock_ok(&self.sh.reg);
            reg.entries
                .iter()
                .map(|e| {
                    (
                        e.key,
                        e.strikes,
                        e.state == KeyState::Quarantined,
                        e.shard,
                    )
                })
                .collect()
        };
        let mut out: Vec<KeyMetrics> = Vec::new();
        for (si, c) in self.sh.cells.iter().enumerate() {
            let st = lock_ok(&c.state);
            for km in &st.keys {
                let owner_here = reg_info
                    .iter()
                    .any(|(k, _, _, home)| *k == km.key && *home == si);
                match out.iter_mut().find(|m| m.key == km.key) {
                    Some(m) => {
                        m.served += km.served;
                        m.batches += km.batches;
                        m.fwd_iters += km.fwd_iters;
                        m.fallback_cols += km.fallback_cols;
                        m.nonfinite_cols += km.nonfinite_cols;
                        m.unconverged += km.unconverged;
                        m.model_faults += km.model_faults;
                        m.calibrations += km.calibrations;
                        m.recalibrations += km.recalibrations;
                        if owner_here {
                            m.fallback_rate = km.fallback_rate;
                            m.estimate_stale = km.estimate_stale;
                            m.breaker = km.breaker;
                        }
                    }
                    None => out.push(km.clone()),
                }
            }
        }
        for (key, strikes, quarantined, _) in &reg_info {
            if !out.iter().any(|m| m.key == *key) {
                out.push(KeyMetrics::new(*key));
            }
            let m = out
                .iter_mut()
                .find(|m| m.key == *key)
                .expect("pushed above");
            m.strikes = *strikes;
            m.quarantined = *quarantined;
        }
        out.sort_by_key(|m| (m.key.model, m.key.version));
        out
    }

    /// Stop the workers (after they drain their queues) and join them.
    /// Dropping the router does the same.
    pub fn shutdown(mut self) {
        self.join_workers();
    }

    fn join_workers(&mut self) {
        if self.handles.is_empty() {
            return;
        }
        self.sh.shutdown.store(true, Ordering::SeqCst);
        for c in &self.sh.cells {
            c.cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        threads::set_active_shards(self.prev_shards);
    }
}

impl<E: Elem, EU: Elem, EV: Elem> Drop for ShardedRouter<E, EU, EV> {
    fn drop(&mut self) {
        self.join_workers();
    }
}

/// Deterministic `ModelKey → shard` hash. Mixes model id and version with
/// distinct odd multipliers so consecutive versions of one model usually
/// land on different shards — the swap's background-calibration shard.
fn affinity_shard(key: ModelKey, shards: usize) -> usize {
    let h = (key.model as u64)
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add((key.version as u64).wrapping_mul(0xD134_2543_DE82_EF95));
    let h = h ^ (h >> 32);
    (h % shards as u64) as usize
}

/// A worker-local engine: built, calibrated, and only ever used on this
/// shard's thread.
struct EngineSlot<E: Elem, EU: Elem, EV: Elem> {
    key: ModelKey,
    engine: ServeEngine<E, EU, EV>,
    model: SharedModel<E>,
}

enum Work {
    Calibrate(ModelKey),
    Batch {
        key: ModelKey,
        base_seq: u64,
        drained_at: f64,
    },
    Idle,
    Exit,
}

/// Supervised shard worker: the serving loop runs inside `catch_unwind`, so
/// a panicking model residual kills one *iteration* of the loop, not the
/// shard. On a panic, [`recover_shard`] reports the in-flight batch as
/// [`ServeError::WorkerLost`] casualties, re-homes the shard's queues if
/// possible, and the loop re-enters [`worker_body`] with fresh worker-local
/// state (engines are rebuilt lazily from the same deterministic z₀ = 0
/// probe, so the respawned shard's estimates are bit-identical).
fn worker_loop<E: Elem, EU: Elem, EV: Elem>(me: usize, sh: Arc<Shared<E>>) {
    loop {
        match catch_unwind(AssertUnwindSafe(|| worker_body::<E, EU, EV>(me, &sh))) {
            Ok(()) => break,
            Err(_) => recover_shard(me, &sh),
        }
    }
}

fn worker_body<E: Elem, EU: Elem, EV: Elem>(me: usize, sh: &Shared<E>) {
    let mut engines: Vec<EngineSlot<E, EU, EV>> = Vec::new();
    let mut items: Vec<(f64, QueuedReq<E>)> = Vec::new();
    let mut expired: Vec<(ModelKey, f64, QueuedReq<E>)> = Vec::new();
    let mut zs: Vec<E> = Vec::new();
    let mut cots: Vec<E> = Vec::new();
    let mut w: Vec<E> = Vec::new();
    let mut stats: Vec<ColStats> = Vec::new();
    let mut poll = STEAL_POLL_S;
    loop {
        match next_work(me, sh, &mut items, &mut expired) {
            Work::Calibrate(key) => {
                calibrate_key(me, sh, &mut engines, key);
                lock_ok(&sh.cells[me].state).active_ctl = None;
                poll = STEAL_POLL_S;
            }
            Work::Batch {
                key,
                base_seq,
                drained_at,
            } => {
                // Deadline-expired entries GC'd by this drain resolve first
                // (their seq stamps follow the live batch), then the live
                // requests are served.
                let live = items.len();
                if !expired.is_empty() {
                    publish_expired(
                        me,
                        sh,
                        &mut expired,
                        base_seq + live as u64,
                        drained_at,
                    );
                }
                if !items.is_empty() {
                    serve_batch(
                        me,
                        sh,
                        &mut engines,
                        key,
                        &mut items,
                        base_seq,
                        drained_at,
                        &mut zs,
                        &mut cots,
                        &mut w,
                        &mut stats,
                    );
                }
                gc_retired(me, sh, &mut engines);
                poll = STEAL_POLL_S;
            }
            Work::Idle => {
                if sh.cfg.steal && try_steal(me, sh) {
                    poll = STEAL_POLL_S;
                    continue;
                }
                gc_retired(me, sh, &mut engines);
                idle_wait(me, sh, poll);
                poll = (poll * 2.0).min(STEAL_POLL_MAX_S);
            }
            Work::Exit => break,
        }
    }
}

/// Post-panic cleanup, run on the worker's own thread before it re-enters
/// [`worker_body`]:
///
/// 1. every in-flight request of the dead batch resolves as a typed
///    [`ServeError::WorkerLost`] response (so `collect` never hangs on a
///    casualty), and an interrupted control op is re-queued so a pending
///    registration is never lost;
/// 2. if other shards exist, every key homed here is re-homed through the
///    whole-queue steal primitives ([`KeyedScheduler::take_queue`] /
///    [`KeyedScheduler::inject_queue`]), preserving FIFO-within-key, so
///    queued requests keep serving even while this shard restarts.
///
/// Lock discipline matches the rest of the file: registry before any shard
/// lock, at most one shard lock at a time.
fn recover_shard<E: Elem>(me: usize, sh: &Shared<E>) {
    let completed = sh.clock.elapsed();
    let (casualties, lost_key, ctl_key) = {
        let mut st = lock_ok(&sh.cells[me].state);
        let lost = std::mem::take(&mut st.inflight);
        let lost_key = st.inflight_key.take();
        let ctl_key = st.active_ctl.take();
        st.stats.respawns += 1;
        st.stats.worker_lost += lost.len();
        (lost, lost_key, ctl_key)
    };
    // Attribute the panic to the key whose work was executing (a batch
    // records `inflight_key`, a calibration probe `active_ctl`) and apply
    // the per-key respawn cap: at `quarantine_after` strikes the key is
    // quarantined and never served again — the fix for the calibration
    // respawn loop (docs/adr/004). Registry lock on its own, before any
    // shard lock below.
    let struck = lost_key.or(ctl_key);
    let mut newly_quarantined = false;
    let mut requeue_ctl = false;
    {
        let mut reg = lock_ok(&sh.reg);
        if let Some(key) = struck {
            if let Some(e) = reg.find_mut(key) {
                e.strikes += 1;
                let cap = sh.cfg.quarantine_after;
                if cap > 0 && e.strikes >= cap && e.state != KeyState::Quarantined {
                    e.state = KeyState::Quarantined;
                    newly_quarantined = true;
                }
            }
        }
        // An interrupted calibration re-queues so a pending registration
        // is never lost — unless the key is quarantined, where re-running
        // the probe would only burn another respawn.
        if let Some(key) = ctl_key {
            requeue_ctl = reg
                .find(key)
                .map(|e| e.state != KeyState::Quarantined)
                .unwrap_or(false);
        }
    }
    if newly_quarantined {
        // Wake register()/wait_live() blockers: the key can never go live.
        sh.reg_cv.notify_all();
    }
    if let Some(key) = ctl_key {
        if requeue_ctl {
            lock_ok(&sh.cells[me].state).ctl.push_front(key);
        }
    }
    if !casualties.is_empty() {
        let key = lost_key.expect("in-flight batch records its key");
        let mut done = lock_ok(&sh.done);
        for c in &casualties {
            done.push(ShardResponse {
                id: c.id,
                key,
                shard: me,
                seq: c.seq,
                z: Vec::new(),
                w: Vec::new(),
                stats: ColStats::default(),
                enqueued: c.enqueued,
                completed,
                error: Some(ServeError::WorkerLost),
            });
        }
        drop(done);
        sh.done_cv.notify_all();
    }
    // Re-home this shard's queues onto the neighbouring shard so queued
    // requests drain without waiting for the respawn (single-shard routers
    // have nowhere to move them; the respawned body serves them instead).
    if sh.cfg.shards > 1 {
        let mut guard = lock_ok(&sh.reg);
        let reg = &mut *guard;
        let target = (me + 1) % sh.cfg.shards;
        let mut moved = false;
        for e in reg.entries.iter_mut().filter(|e| e.shard == me) {
            let q = {
                let mut st = lock_ok(&sh.cells[me].state);
                st.sched.take_queue(e.key)
            };
            if let Some(q) = q {
                if !q.is_empty() {
                    e.shard = target;
                    e.steal_cooldown = STEAL_COOLDOWN_BATCHES;
                    let mut st = lock_ok(&sh.cells[target].state);
                    st.sched.inject_queue(e.key, q);
                    moved = true;
                }
            }
        }
        drop(guard);
        if moved {
            sh.cells[target].cv.notify_one();
        }
    }
}

/// Resolve deadline-expired entries GC'd at drain time as typed
/// [`ServeError::DeadlineExceeded`] responses (empty `z`/`w` — the solve
/// never ran).
fn publish_expired<E: Elem>(
    me: usize,
    sh: &Shared<E>,
    expired: &mut Vec<(ModelKey, f64, QueuedReq<E>)>,
    base_seq: u64,
    drained_at: f64,
) {
    let n = expired.len();
    let completed = sh.clock.elapsed();
    {
        let mut done = lock_ok(&sh.done);
        for (p, (key, wait, q)) in expired.drain(..).enumerate() {
            done.push(ShardResponse {
                id: q.id,
                key,
                shard: me,
                seq: base_seq + p as u64,
                z: Vec::new(),
                w: Vec::new(),
                stats: ColStats::default(),
                enqueued: drained_at - wait,
                completed,
                error: Some(ServeError::DeadlineExceeded),
            });
        }
    }
    sh.done_cv.notify_all();
    let mut st = lock_ok(&sh.cells[me].state);
    st.stats.deadline_expired += n;
}

/// Pick the shard's next unit of work under its own lock: control ops
/// first, then a releasable batch (drained into `items` with admission
/// stamps assigned *while the lock is held* — the FIFO-within-key
/// witness), else idle / exit. Deadline-expired entries GC'd by the drain
/// land in `expired` (stamped after the live batch); the in-flight batch is
/// recorded in the shard state under the same lock so supervision can
/// resolve it as [`ServeError::WorkerLost`] if the worker dies serving it.
fn next_work<E: Elem>(
    me: usize,
    sh: &Shared<E>,
    items: &mut Vec<(f64, QueuedReq<E>)>,
    expired: &mut Vec<(ModelKey, f64, QueuedReq<E>)>,
) -> Work {
    let mut st = lock_ok(&sh.cells[me].state);
    if let Some(key) = st.ctl.pop_front() {
        st.active_ctl = Some(key);
        return Work::Calibrate(key);
    }
    let now = sh.clock.elapsed();
    if let Some((key, n)) = st.sched.ready(now) {
        items.clear();
        expired.clear();
        st.sched.drain_key(key, n, now, items);
        st.sched.take_expired(expired);
        let total = (items.len() + expired.len()) as u64;
        let base_seq = sh.seq.fetch_add(total, Ordering::SeqCst);
        st.inflight_key = (!items.is_empty()).then_some(key);
        st.inflight = items
            .iter()
            .enumerate()
            .map(|(p, (wait, q))| InFlight {
                id: q.id,
                seq: base_seq + p as u64,
                enqueued: now - wait,
            })
            .collect();
        return Work::Batch {
            key,
            base_seq,
            drained_at: now,
        };
    }
    if sh.shutdown.load(Ordering::SeqCst) && st.sched.is_empty() {
        return Work::Exit;
    }
    Work::Idle
}

/// Build + calibrate a worker-local engine for `key` (idempotent).
fn build_engine<E: Elem, EU: Elem, EV: Elem>(
    me: usize,
    sh: &Shared<E>,
    engines: &mut Vec<EngineSlot<E, EU, EV>>,
    key: ModelKey,
    model: &SharedModel<E>,
) {
    if engines.iter().any(|s| s.key == key) {
        return;
    }
    let d = model.dim();
    let mut engine: ServeEngine<E, EU, EV> = ServeEngine::new(d, sh.cfg.engine);
    engine.calibrate(
        |z: &[E], out: &mut [E]| model.residual_batch(z, 1, out),
        &vec![E::ZERO; d],
    );
    engines.push(EngineSlot {
        key,
        engine,
        model: Arc::clone(model),
    });
    let mut st = lock_ok(&sh.cells[me].state);
    st.stats.calibrations += 1;
    st.stats.engine_keys = engines.iter().map(|s| s.key).collect();
    st.key_entry(key).calibrations += 1;
}

/// Background calibration + the blue/green cutover (see module docs).
fn calibrate_key<E: Elem, EU: Elem, EV: Elem>(
    me: usize,
    sh: &Shared<E>,
    engines: &mut Vec<EngineSlot<E, EU, EV>>,
    key: ModelKey,
) {
    let model = {
        let reg = lock_ok(&sh.reg);
        match reg.find(key) {
            Some(e) => Arc::clone(&e.model),
            // Retired and collected before we got to it: drop the op.
            None => return,
        }
    };
    build_engine(me, sh, engines, key, &model);
    // Atomic cutover under the registry lock: bump the live route and
    // retire exactly the previous live version of this model id.
    {
        let mut guard = lock_ok(&sh.reg);
        let reg = &mut *guard;
        if let Some(e) = reg.find_mut(key) {
            e.state = KeyState::Live;
        }
        match reg.live.iter_mut().find(|(m, _)| *m == key.model) {
            None => reg.live.push((key.model, key.version)),
            Some(entry) if entry.1 < key.version => {
                let old = ModelKey::new(key.model, entry.1);
                entry.1 = key.version;
                if let Some(e) = reg.find_mut(old) {
                    e.state = KeyState::Retired;
                }
            }
            // Stale replay: never tear down a newer live version.
            Some(_) => {}
        }
    }
    sh.reg_cv.notify_all();
}

/// Serve one single-key batch on this shard's private engine, then publish
/// the responses. Mirrors [`crate::serve::Router::process`] including the
/// trip-rate re-calibration policy.
#[allow(clippy::too_many_arguments)]
fn serve_batch<E: Elem, EU: Elem, EV: Elem>(
    me: usize,
    sh: &Shared<E>,
    engines: &mut Vec<EngineSlot<E, EU, EV>>,
    key: ModelKey,
    items: &mut Vec<(f64, QueuedReq<E>)>,
    base_seq: u64,
    drained_at: f64,
    zs: &mut Vec<E>,
    cots: &mut Vec<E>,
    w: &mut Vec<E>,
    stats: &mut Vec<ColStats>,
) {
    // A quarantined key is never served again: every queued request
    // resolves as a typed `ModelFault` without running the solve (the
    // panic loop already consumed its respawn budget). Registry lock
    // taken and released before the done/shard locks below.
    let quarantined = {
        let reg = lock_ok(&sh.reg);
        matches!(
            reg.find(key).map(|e| e.state),
            Some(KeyState::Quarantined)
        )
    };
    if quarantined {
        let completed = sh.clock.elapsed();
        let b = items.len();
        {
            let mut done = lock_ok(&sh.done);
            for (p, (wait, req)) in items.drain(..).enumerate() {
                done.push(ShardResponse {
                    id: req.id,
                    key,
                    shard: me,
                    seq: base_seq + p as u64,
                    z: Vec::new(),
                    w: Vec::new(),
                    stats: ColStats::default(),
                    enqueued: drained_at - wait,
                    completed,
                    error: Some(ServeError::ModelFault),
                });
            }
        }
        sh.done_cv.notify_all();
        let mut st = lock_ok(&sh.cells[me].state);
        st.inflight.clear();
        st.inflight_key = None;
        st.stats.served += b;
        st.stats.quarantined += b;
        let km = st.key_entry(key);
        km.served += b;
        km.model_faults += b;
        return;
    }
    if !engines.iter().any(|s| s.key == key) {
        // First batch after a steal: calibrate a local engine from the
        // same deterministic z₀ = 0 probe — bit-identical to the home
        // shard's estimate, which therefore never crosses threads.
        let model = {
            let reg = lock_ok(&sh.reg);
            Arc::clone(&reg.find(key).expect("queued key is registered").model)
        };
        build_engine(me, sh, engines, key, &model);
    }
    let pos = engines.iter().position(|s| s.key == key).expect("engine built");
    let slot = &mut engines[pos];
    let d = slot.model.dim();
    let b = items.len();
    zs.clear();
    zs.resize(b * d, E::ZERO);
    cots.clear();
    cots.resize(b * d, E::ZERO);
    w.clear();
    w.resize(b * d, E::ZERO);
    stats.clear();
    stats.resize(b, ColStats::default());
    for (p, (_, req)) in items.iter().enumerate() {
        zs[p * d..(p + 1) * d].copy_from_slice(&req.z0);
        cots[p * d..(p + 1) * d].copy_from_slice(&req.cot);
    }
    let model = &slot.model;
    // The engine hands physical column indices; map them back to caller
    // request ids so per-request fault injection (and any id-aware model)
    // keys off the submitted id, not the batch slot.
    let req_ids: Vec<usize> = items.iter().map(|(_, q)| q.id).collect();
    let mut idbuf: Vec<usize> = Vec::with_capacity(b);
    let report = slot.engine.process(
        |block: &[E], cols: &[usize], out: &mut [E]| {
            idbuf.clear();
            idbuf.extend(cols.iter().map(|&c| req_ids[c]));
            model.residual_batch_ids(block, &idbuf, out)
        },
        &mut zs[..],
        &cots[..],
        &mut w[..],
        &mut stats[..],
    );
    let mut recalibrated = false;
    if report.estimate_stale {
        slot.engine.invalidate_estimate();
        slot.engine.calibrate(
            |z: &[E], out: &mut [E]| model.residual_batch(z, 1, out),
            &vec![E::ZERO; d],
        );
        recalibrated = true;
    }
    // Engine gauges for the per-key metrics row, read before any lock.
    let trip_rate = slot.engine.trip_rate();
    let stale = slot.engine.estimate_stale();
    let breaker = slot
        .engine
        .breaker()
        .map(|br| br.state())
        .unwrap_or(BreakerState::Closed);
    let mut model_faults = 0usize;
    let mut unconverged = 0usize;
    let completed = sh.clock.elapsed();
    {
        let mut done = lock_ok(&sh.done);
        for (p, (wait, req)) in items.drain(..).enumerate() {
            let zc = &zs[p * d..(p + 1) * d];
            let wc = &w[p * d..(p + 1) * d];
            // Per-column outcome: non-finite anywhere in the column's fixed
            // point, backward answer, or final residual is a ModelFault
            // (best-effort values still attached); a finite column that
            // missed tolerance is Unconverged.
            let finite = stats[p].residual.is_finite()
                && zc.iter().chain(wc.iter()).all(|v| v.to_f64().is_finite());
            let error = if !finite {
                model_faults += 1;
                Some(ServeError::ModelFault)
            } else if !stats[p].converged {
                unconverged += 1;
                Some(ServeError::Unconverged)
            } else {
                None
            };
            done.push(ShardResponse {
                id: req.id,
                key,
                shard: me,
                seq: base_seq + p as u64,
                z: zc.to_vec(),
                w: wc.to_vec(),
                stats: stats[p],
                enqueued: drained_at - wait,
                completed,
                error,
            });
        }
    }
    sh.done_cv.notify_all();
    // Steal hysteresis: a served batch is one unit of cooldown progress for
    // this key (registry lock taken on its own, before the shard lock below
    // — the global lock order).
    if sh.cfg.steal {
        let mut reg = lock_ok(&sh.reg);
        if let Some(e) = reg.find_mut(key) {
            e.steal_cooldown = e.steal_cooldown.saturating_sub(1);
        }
    }
    let mut st = lock_ok(&sh.cells[me].state);
    // The batch's responses are published: clearing the in-flight record
    // here (and only here) is what makes every request resolve exactly once
    // — the publish path above has no panic sources, so supervision can
    // never double-report a batch it has already seen resolved.
    st.inflight.clear();
    st.inflight_key = None;
    st.stats.served += b;
    st.stats.batches += 1;
    if recalibrated {
        st.stats.recalibrations += 1;
    }
    st.stats.open_breakers = engines.iter().filter(|s| s.engine.breaker_open()).count();
    let km = st.key_entry(key);
    km.served += b;
    km.batches += 1;
    km.fwd_iters += report.fwd_col_iters_total;
    km.fallback_cols += report.fallback_cols;
    km.nonfinite_cols += report.nonfinite_cols;
    km.unconverged += unconverged;
    km.model_faults += model_faults;
    km.fallback_rate = trip_rate;
    km.estimate_stale = stale;
    km.breaker = breaker;
    if recalibrated {
        km.recalibrations += 1;
    }
}

/// Collect retired keys this shard owns once their queues drain: remove
/// the registry entry and drop the local engine — the "invalidate exactly
/// that key" half of the swap protocol. Also drops engines for keys whose
/// entries another shard already collected (e.g. after a historic steal).
fn gc_retired<E: Elem, EU: Elem, EV: Elem>(
    me: usize,
    sh: &Shared<E>,
    engines: &mut Vec<EngineSlot<E, EU, EV>>,
) {
    let mut guard = lock_ok(&sh.reg);
    let reg = &mut *guard;
    let mut st = lock_ok(&sh.cells[me].state);
    let sched = &st.sched;
    reg.entries.retain(|e| {
        !(e.state == KeyState::Retired && e.shard == me && sched.count_key(e.key) == 0)
    });
    let before = engines.len();
    engines.retain(|s| reg.entries.iter().any(|e| e.key == s.key));
    if engines.len() != before {
        st.stats.engine_keys = engines.iter().map(|s| s.key).collect();
    }
}

/// Steal the entire queue of a backlogged key from another shard. The
/// victim signal is precise: a key whose batch is *releasable right now*
/// (`ready()` non-empty) on a shard that has not picked it up — so stolen
/// work is immediately actionable on the thief and idle shards never
/// ping-pong not-yet-ready queues. Registry lock held throughout; at most
/// one shard lock at a time.
fn try_steal<E: Elem>(me: usize, sh: &Shared<E>) -> bool {
    let mut guard = lock_ok(&sh.reg);
    let reg = &mut *guard;
    let now = sh.clock.elapsed();
    let mut best: Option<(usize, ModelKey, usize)> = None;
    for j in 0..sh.cfg.shards {
        if j == me {
            continue;
        }
        let st = lock_ok(&sh.cells[j].state);
        if let Some((key, n)) = st.sched.ready(now) {
            // A key in steal cooldown stays with its current owner — the
            // hysteresis that stops ownership bouncing under alternating
            // load (each bounce would re-home the queue and charge a new
            // owner a calibration probe).
            let stealable = reg
                .find(key)
                .map(|e| e.shard == j && e.steal_cooldown == 0)
                .unwrap_or(false);
            if stealable && best.map(|(_, _, bn)| n > bn).unwrap_or(true) {
                best = Some((j, key, n));
            }
        }
    }
    let Some((victim, key, _)) = best else {
        return false;
    };
    let q = {
        let mut vst = lock_ok(&sh.cells[victim].state);
        // The victim may have drained it between the probe and now.
        match vst.sched.take_queue(key) {
            Some(q) if !q.is_empty() => q,
            _ => return false,
        }
    };
    // Re-home the key in the same registry critical section, so arrivals
    // after the steal follow the queue (FIFO-within-key survives), and
    // stamp the cooldown that keeps it here until the new owner has served
    // [`STEAL_COOLDOWN_BATCHES`] batches of it.
    if let Some(e) = reg.find_mut(key) {
        e.shard = me;
        e.steal_cooldown = STEAL_COOLDOWN_BATCHES;
    }
    let mut st = lock_ok(&sh.cells[me].state);
    st.sched.inject_queue(key, q);
    st.stats.steals += 1;
    true
}

/// Sleep until notified (submit / control / shutdown), a queued partial
/// batch's deadline, or the steal-poll timeout — whichever is soonest.
fn idle_wait<E: Elem>(me: usize, sh: &Shared<E>, poll: f64) {
    let cell = &sh.cells[me];
    let st = lock_ok(&cell.state);
    // Re-check under the lock so a wakeup between next_work and here is
    // not slept through.
    if !st.ctl.is_empty() || sh.shutdown.load(Ordering::SeqCst) {
        return;
    }
    let now = sh.clock.elapsed();
    if st.sched.ready(now).is_some() {
        return;
    }
    let mut wait = if sh.cfg.steal { poll } else { 0.05 };
    if let Some(t) = st.sched.next_deadline() {
        wait = wait.min((t - now).max(0.0));
    }
    let _ = cell
        .cv
        .wait_timeout(st, Duration::from_secs_f64(wait))
        .unwrap_or_else(|p| p.into_inner());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::synth::SynthDeq;

    #[test]
    fn affinity_is_deterministic_and_in_range() {
        for shards in [1usize, 2, 3, 4, 7] {
            for m in 0..16u32 {
                for v in 0..4u32 {
                    let k = ModelKey::new(m, v);
                    let s = affinity_shard(k, shards);
                    assert!(s < shards);
                    assert_eq!(s, affinity_shard(k, shards), "deterministic");
                }
            }
        }
        // One shard degenerates to the single-threaded placement.
        assert_eq!(affinity_shard(ModelKey::new(3, 1), 1), 0);
    }

    #[test]
    fn version_mixing_spreads_rolls() {
        // Consecutive versions of one model should not all collapse onto
        // one shard (the swap wants a background shard to calibrate on).
        let shards = 4;
        let homes: Vec<usize> = (0..8u32)
            .map(|v| affinity_shard(ModelKey::new(0, v), shards))
            .collect();
        assert!(
            homes.iter().any(|s| *s != homes[0]),
            "all versions hashed to shard {}: {homes:?}",
            homes[0]
        );
    }

    /// A [`Shared`] with no worker threads, so the steal/serve protocol
    /// can be driven by hand on one thread — fully deterministic, no
    /// scheduler timing involved. `max_wait = 0` makes every queued
    /// request immediately releasable.
    fn bare_shared(shards: usize, max_batch: usize) -> Arc<Shared<f64>> {
        let sched = SchedulerConfig {
            max_batch,
            max_wait: 0.0,
            queue_cap: 64,
        };
        let cfg = ShardConfig::new(
            shards,
            EngineConfig {
                max_batch,
                ..Default::default()
            }
            .with_tol(1e-8),
            sched,
        );
        Arc::new(Shared {
            cfg,
            reg: Mutex::new(Registry {
                entries: Vec::new(),
                live: Vec::new(),
            }),
            reg_cv: Condvar::new(),
            cells: (0..shards)
                .map(|_| ShardCell {
                    state: Mutex::new(ShardState::new(sched)),
                    cv: Condvar::new(),
                })
                .collect(),
            done: Mutex::new(Vec::new()),
            done_cv: Condvar::new(),
            seq: AtomicU64::new(0),
            clock: Stopwatch::start(),
            shutdown: AtomicBool::new(false),
        })
    }

    #[test]
    fn steal_cooldown_blocks_ownership_bouncing() {
        // The bounce regression: under alternating load a ready queue on
        // the current owner used to be immediately re-stealable by the
        // shard it just left, ping-ponging ownership (and charging each
        // first-time owner a calibration probe). The cooldown must (a) not
        // delay the FIRST steal of a fresh key, (b) pin the key to its new
        // owner for STEAL_COOLDOWN_BATCHES served batches, (c) release it
        // afterwards.
        let d = 16;
        let b = 2usize;
        let sh = bare_shared(2, b);
        let key = ModelKey::new(0, 0);
        let model: SharedModel<f64> = Arc::new(SynthDeq::<f64>::new(d, 8, 1));
        {
            let mut reg = sh.reg.lock().unwrap();
            reg.entries.push(RegEntry {
                key,
                model: Arc::clone(&model),
                shard: 0,
                state: KeyState::Live,
                steal_cooldown: 0,
                strikes: 0,
            });
            reg.live.push((0, 0));
        }
        let push_batch = |shard: usize, base: usize| {
            let mut st = sh.cells[shard].state.lock().unwrap();
            for i in 0..b {
                let req = QueuedReq {
                    id: base + i,
                    z0: vec![0.0; d],
                    cot: vec![1.0; d],
                };
                assert!(st.sched.push(0.0, key, req).is_ok());
            }
        };
        // (a) a ready batch on the home shard: the idle shard 1 steals it
        // immediately — fresh keys carry no cooldown.
        push_batch(0, 0);
        assert!(try_steal(1, &sh), "first steal is never delayed");
        {
            let reg = sh.reg.lock().unwrap();
            let e = reg.find(key).unwrap();
            assert_eq!(e.shard, 1, "key re-homed to the thief");
            assert_eq!(e.steal_cooldown, STEAL_COOLDOWN_BATCHES);
        }
        // (b) the queue is ready on the thief and shard 0 is idle — the
        // exact bounce configuration. Serve the cooldown out on shard 1,
        // re-offering a ready batch (alternating load) each round; shard 0
        // must not reclaim the key until the cooldown is spent.
        assert!(!try_steal(0, &sh), "cooldown blocks the immediate re-steal");
        let mut engines: Vec<EngineSlot<f64, f64, f64>> = Vec::new();
        let mut items = Vec::new();
        let mut expired = Vec::new();
        let (mut zs, mut cots, mut w) = (Vec::new(), Vec::new(), Vec::new());
        let mut stats = Vec::new();
        for round in 0..STEAL_COOLDOWN_BATCHES {
            let Work::Batch {
                key: k,
                base_seq,
                drained_at,
            } = next_work(1, &sh, &mut items, &mut expired)
            else {
                panic!("round {round}: expected a releasable batch on shard 1");
            };
            assert_eq!(k, key);
            serve_batch(
                1, &sh, &mut engines, k, &mut items, base_seq, drained_at, &mut zs, &mut cots,
                &mut w, &mut stats,
            );
            let left = sh.reg.lock().unwrap().find(key).unwrap().steal_cooldown;
            assert_eq!(left, STEAL_COOLDOWN_BATCHES - 1 - round);
            push_batch(1, 100 * (round as usize + 1));
            if round + 1 < STEAL_COOLDOWN_BATCHES {
                assert!(
                    !try_steal(0, &sh),
                    "round {round}: {left} cooldown batches left must still block"
                );
            }
        }
        // (c) cooldown spent: the ready queue is stealable again, and the
        // steal restamps the cooldown for the next owner.
        assert!(try_steal(0, &sh), "expired cooldown releases the key");
        let reg = sh.reg.lock().unwrap();
        let e = reg.find(key).unwrap();
        assert_eq!(e.shard, 0);
        assert_eq!(e.steal_cooldown, STEAL_COOLDOWN_BATCHES);
        // Exactly one calibration happened on the thief across the whole
        // cooldown window — the cost the hysteresis caps.
        assert_eq!(sh.cells[1].state.lock().unwrap().stats.calibrations, 1);
    }

    #[test]
    fn submit_unknown_model_is_rejected() {
        let cfg = ShardConfig::new(
            2,
            EngineConfig {
                max_batch: 4,
                ..Default::default()
            },
            SchedulerConfig {
                max_batch: 4,
                max_wait: 1e-4,
                queue_cap: 16,
            },
        );
        let router: ShardedRouter<f64> = ShardedRouter::new(cfg);
        let req = ShardRequest::new(0, vec![0.0; 8], vec![1.0; 8]);
        match router.submit(9, req) {
            Err(SubmitError::UnknownModel(r)) => assert_eq!(r.id, 0),
            other => panic!("expected UnknownModel, got {other:?}"),
        }
        router.shutdown();
    }

    #[test]
    fn single_shard_end_to_end() {
        let d = 24;
        let cfg = ShardConfig::new(
            1,
            EngineConfig {
                max_batch: 4,
                ..Default::default()
            }
            .with_tol(1e-8),
            SchedulerConfig {
                max_batch: 4,
                max_wait: 1e-4,
                queue_cap: 64,
            },
        );
        let router: ShardedRouter<f64> = ShardedRouter::new(cfg);
        let key = ModelKey::new(0, 0);
        router.register(key, Arc::new(SynthDeq::<f64>::new(d, 8, 1)));
        assert_eq!(router.live_version(0), Some(0));
        for id in 0..8usize {
            let req = ShardRequest::new(id, vec![0.0; d], vec![1.0; d]);
            router.submit(0, req).expect("routed");
        }
        let mut out = router.collect(8);
        assert_eq!(out.len(), 8);
        out.sort_by_key(|r| r.id);
        for (i, r) in out.iter().enumerate() {
            assert_eq!(r.id, i);
            assert_eq!(r.key, key);
            assert_eq!(r.shard, 0);
            assert!(r.ok(), "request {i} served: {:?}", r.error);
            assert!(r.stats.converged, "request {i} converged");
            assert!(r.completed >= r.enqueued);
        }
        // All eight solve the same problem from the same start: identical.
        for r in &out[1..] {
            assert_eq!(r.z, out[0].z);
            assert_eq!(r.w, out[0].w);
        }
        let stats = router.shard_stats();
        assert_eq!(stats[0].served, 8);
        assert_eq!(stats[0].engine_keys, vec![key]);
        router.shutdown();
    }

    #[test]
    fn lock_ok_recovers_a_poisoned_mutex() {
        let m = Arc::new(Mutex::new(7usize));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.lock().is_err(), "mutex is poisoned");
        // lock_ok sees through the poison and the data is intact.
        assert_eq!(*lock_ok(&m), 7);
        *lock_ok(&m) += 1;
        assert_eq!(*lock_ok(&m), 8);
    }

    #[test]
    fn expired_entries_resolve_as_deadline_exceeded() {
        let d = 16;
        let sh = bare_shared(1, 4);
        let key = ModelKey::new(0, 0);
        let model: SharedModel<f64> = Arc::new(SynthDeq::<f64>::new(d, 8, 1));
        {
            let mut reg = sh.reg.lock().unwrap();
            reg.entries.push(RegEntry {
                key,
                model: Arc::clone(&model),
                shard: 0,
                state: KeyState::Live,
                steal_cooldown: 0,
                strikes: 0,
            });
            reg.live.push((0, 0));
        }
        {
            let mut st = sh.cells[0].state.lock().unwrap();
            let q = |id: usize| QueuedReq {
                id,
                z0: vec![0.0; d],
                cot: vec![1.0; d],
            };
            // id 0 never expires; id 1's deadline is already in the past by
            // the time next_work drains (absolute deadline 0 on a running
            // clock).
            assert!(st.sched.push_deadline(0.0, f64::INFINITY, key, q(0)).is_ok());
            assert!(st.sched.push_deadline(0.0, 0.0, key, q(1)).is_ok());
        }
        let mut items = Vec::new();
        let mut expired = Vec::new();
        let Work::Batch {
            key: k,
            base_seq,
            drained_at,
        } = next_work(0, &sh, &mut items, &mut expired)
        else {
            panic!("expected a releasable batch");
        };
        assert_eq!(k, key);
        assert_eq!(items.len(), 1, "live request drained");
        assert_eq!(expired.len(), 1, "expired request diverted");
        // Mirror worker_body's Batch arm: expired first (stamped after the
        // live batch), then the live request serves.
        publish_expired(0, &sh, &mut expired, base_seq + 1, drained_at);
        let mut engines: Vec<EngineSlot<f64, f64, f64>> = Vec::new();
        let (mut zs, mut cots, mut w) = (Vec::new(), Vec::new(), Vec::new());
        let mut stats = Vec::new();
        serve_batch(
            0, &sh, &mut engines, key, &mut items, base_seq, drained_at, &mut zs, &mut cots,
            &mut w, &mut stats,
        );
        let mut done = sh.done.lock().unwrap();
        done.sort_by_key(|r| r.id);
        assert_eq!(done.len(), 2, "both requests resolved");
        assert!(done[0].ok() && done[0].stats.converged);
        assert_eq!(done[0].seq, base_seq);
        assert_eq!(done[1].error, Some(ServeError::DeadlineExceeded));
        assert_eq!(done[1].seq, base_seq + 1);
        assert!(done[1].z.is_empty() && done[1].w.is_empty());
        drop(done);
        let st = sh.cells[0].state.lock().unwrap();
        assert_eq!(st.stats.deadline_expired, 1);
        assert!(st.inflight.is_empty() && st.inflight_key.is_none());
    }

    #[test]
    fn recover_shard_reports_casualties_and_rehomes_queues() {
        let d = 16;
        let sh = bare_shared(2, 4);
        let key = ModelKey::new(0, 0);
        let model: SharedModel<f64> = Arc::new(SynthDeq::<f64>::new(d, 8, 1));
        {
            let mut reg = sh.reg.lock().unwrap();
            reg.entries.push(RegEntry {
                key,
                model: Arc::clone(&model),
                shard: 0,
                state: KeyState::Live,
                steal_cooldown: 0,
                strikes: 0,
            });
            reg.live.push((0, 0));
        }
        {
            let mut st = sh.cells[0].state.lock().unwrap();
            // A queued request that survives the crash...
            let q = QueuedReq {
                id: 10,
                z0: vec![0.0; d],
                cot: vec![1.0; d],
            };
            assert!(st.sched.push(0.0, key, q).is_ok());
            // ...an in-flight batch that does not...
            st.inflight_key = Some(key);
            st.inflight = vec![
                InFlight { id: 0, seq: 5, enqueued: 0.0 },
                InFlight { id: 1, seq: 6, enqueued: 0.0 },
            ];
            // ...and an interrupted control op.
            st.active_ctl = Some(ModelKey::new(3, 0));
        }
        recover_shard(0, &sh);
        let mut done = sh.done.lock().unwrap();
        done.sort_by_key(|r| r.id);
        assert_eq!(done.len(), 2, "both in-flight requests resolved");
        for (r, (id, seq)) in done.iter().zip([(0usize, 5u64), (1, 6)]) {
            assert_eq!(r.id, id);
            assert_eq!(r.seq, seq);
            assert_eq!(r.error, Some(ServeError::WorkerLost));
            assert!(r.z.is_empty() && r.w.is_empty());
        }
        drop(done);
        {
            let st = sh.cells[0].state.lock().unwrap();
            assert_eq!(st.stats.respawns, 1);
            assert_eq!(st.stats.worker_lost, 2);
            assert!(st.inflight.is_empty() && st.inflight_key.is_none());
            assert_eq!(st.ctl.front(), Some(&ModelKey::new(3, 0)), "ctl re-queued");
            assert_eq!(st.sched.len(), 0, "queue moved off the dead shard");
        }
        let reg = sh.reg.lock().unwrap();
        assert_eq!(reg.find(key).unwrap().shard, 1, "key re-homed");
        assert_eq!(
            reg.find(key).unwrap().steal_cooldown,
            STEAL_COOLDOWN_BATCHES
        );
        drop(reg);
        let st = sh.cells[1].state.lock().unwrap();
        assert_eq!(st.sched.count_key(key), 1, "queued request followed the key");
    }

    #[test]
    fn submit_rejects_expired_deadline_at_admission() {
        let cfg = ShardConfig::new(
            1,
            EngineConfig {
                max_batch: 4,
                ..Default::default()
            },
            SchedulerConfig {
                max_batch: 4,
                max_wait: 1e-4,
                queue_cap: 16,
            },
        );
        let router: ShardedRouter<f64> = ShardedRouter::new(cfg);
        let key = ModelKey::new(0, 0);
        router.register(key, Arc::new(SynthDeq::<f64>::new(8, 8, 1)));
        let mut req = ShardRequest::new(0, vec![0.0; 8], vec![1.0; 8]);
        req.deadline = Some(0.0); // already in the past on the router clock
        match router.submit(0, req) {
            Err(SubmitError::DeadlineExceeded(r)) => {
                assert_eq!(r.id, 0);
                assert_eq!(
                    SubmitError::DeadlineExceeded(r).as_serve_error(),
                    ServeError::DeadlineExceeded
                );
            }
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        router.shutdown();
    }
}
