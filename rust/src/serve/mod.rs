//! Batched DEQ serving engine: B concurrent requests as matrix-level work.
//!
//! The repo's fastest kernels — the contiguous `FactorPanel` sweeps, the
//! multi-RHS `apply_t_multi`, the thread-sharded batched residual — all
//! batch well, but below this module nothing amortized many per-request
//! solves into shared sweeps. This subsystem closes that gap and turns the
//! SHINE machinery into a traffic-serving scenario:
//!
//! * **Batched forward** — requests are packed into one contiguous d × B
//!   column-major state block and solved by [`picard_solve_batch`] /
//!   [`AndersonBatch`] (see [`crate::solvers::fixed_point`]): the model
//!   residual is evaluated ONCE per iteration over the whole block (one
//!   thread fan-out per iteration instead of one per request), converged
//!   columns retire by swap-to-back compaction so late iterations only
//!   touch stragglers, and every column's trajectory is bit-identical to a
//!   sequential solve.
//! * **One-sweep SHINE backward** — the engine holds a single
//!   `LowRank` inverse estimate captured from a Broyden calibration probe
//!   (the forward pass's qN estimate, exactly what SHINE shares per the
//!   paper) and answers ALL B cotangents of a batch with one
//!   `apply_t_multi_into` panel sweep: the factor panels are streamed once
//!   per batch, not once per request, and the coefficient block comes from
//!   the engine's [`Workspace`] so a steady-state batch allocates nothing.
//! * **Micro-batching front end** — [`Scheduler`] drains a bounded FIFO
//!   queue into batches by max-batch-size / max-wait, and
//!   [`loadgen::run_closed_loop`] drives a synthetic closed-loop load
//!   through scheduler + engine (the `serve-bench` CLI subcommand and
//!   `benches/serve_throughput.rs` both sit on it).
//! * **Continuous batching** — [`ServeEngine::process_streaming`] keeps a
//!   long-lived in-flight d × B block and admits requests into columns
//!   freed by retirement **mid-solve** (no drain → solve → drain cycles):
//!   each column carries its own iteration counter and budget, injected
//!   columns have their per-column solver state reset without perturbing
//!   neighbours (so every request follows the bit-identical solo
//!   trajectory from its injection point), stragglers that exceed
//!   [`EngineConfig::col_budget`] are **evicted for retry** with their
//!   iterate preserved, and the admission width is polled per sweep — the
//!   hook for the per-key [`AdaptiveWidth`] AIMD controller. The
//!   [`loadgen::run_open_loop`] driver measures it against discrete batch
//!   formation under Poisson/Pareto open-loop arrivals.
//! * **Sharded scale-out** — [`ShardedRouter`] spawns N scheduler shards on
//!   `std::thread` workers, routes every key's traffic to one shard by
//!   affinity hash (the per-key calibration estimate never crosses
//!   threads), steals **whole per-key queues** from backlogged shards
//!   (FIFO-within-key survives), and rolls model versions with zero
//!   downtime — background calibration, atomic cutover, retire-and-drain
//!   of exactly the old key. Per-request results are bit-identical to the
//!   single-shard router (pinned in `rust/tests/serve_shard.rs`); the
//!   [`loadgen::run_sharded_open_loop`] driver produces the shard-scaling
//!   and live-swap cells of `BENCH_serve.json`. A stolen key enters a
//!   served-batch *cooldown* ([`shard::STEAL_COOLDOWN_BATCHES`]) before it
//!   can be stolen again, so ownership cannot ping-pong under alternating
//!   load.
//! * **Reduced-precision panel storage** — [`ServeEngine`], [`Router`] and
//!   [`ShardedRouter`] carry two optional storage parameters
//!   (`<E, EU = E, EV = EU>`) selecting the precision of the cached
//!   estimate's U and V factor panels. Calibration always runs at the state
//!   precision `E`; the resulting `LowRank<E>` is *demoted* into
//!   `LowRank<EU, EV>` storage (`LowRank::convert`) before caching, and the
//!   blanket `InvOp` impl applies it to `E` batches with f64 accumulation.
//!   The accuracy-critical **mixed layout** (`<f32, Bf16, f32>`) stores U
//!   in bf16 — where the backward sweep's memory traffic lives — and keeps
//!   the coefficient-sweep V side in f32; the §3 fallback guard plus
//!   [`RecalibPolicy`] bound the damage if demoted estimates ever degrade
//!   (see `docs/adr/003-reduced-precision-panels.md`). Training and
//!   calibration precision are untouched — reduced precision is a pure
//!   serving-storage decision, selected per instantiation (and per
//!   [`ModelKey`] by running distinct router instantiations).
//!
//! # Invariants and contracts
//!
//! **Retirement / compaction** (both batched solvers): the active columns
//! always form the prefix `0..active` of the block; a column whose residual
//! reaches `tol` (or whose iteration budget is exhausted) swaps with column
//! `active-1` — state, residual and (for Anderson) per-column solver state
//! travel together — and `active` shrinks. `ids[p]` names the caller-side
//! column physically at `p`; the residual closure receives it so
//! per-request context (input injections) can be looked up per column. On
//! return the block is un-permuted to submission order (cycle walk), so
//! callers never observe the compaction.
//!
//! **Workspace reuse**: one `Workspace` lives in the engine and is threaded
//! through every forward solve and backward sweep. All transient state —
//! the residual block, the column-id permutation ([`Workspace::take_idx`]),
//! Anderson histories/Gram systems, multi-RHS panel coefficients — is
//! drawn from its pools, and the Anderson per-column states persist across
//! batches inside the engine ([`AndersonBatch`]), recycling their history
//! buffers on reset. After the first full-depth batch, `process` performs
//! **zero heap allocations per batch** (proven by the serving case in
//! `rust/tests/qn_alloc.rs`).
//!
//! **Scheduler semantics**: bounded FIFO queue; `push` rejects when full
//! (backpressure, never unbounded growth). A full batch (`max_batch`
//! requests) is releasable immediately; a partial batch only once the
//! *oldest* queued request has waited `max_wait`. Draining hands back
//! per-request queue latency so the load generator can report end-to-end
//! latency (queue wait + batch service). Streaming admission pulls single
//! requests instead ([`KeyedScheduler::pop_front_key`]) and **never
//! reorders FIFO within a key** (pinned in `rust/tests/serve_batch.rs`).
//!
//! **Streaming retirement ordering**: retirement classification runs
//! *converged → budget-exhausted → evicted* per column, each sweep's
//! retiring cotangents are answered in ONE multi-RHS panel sweep (the §3
//! guard applied per wave column), and evicted columns leave with their
//! iterate intact and an empty backward — re-admission continues the solo
//! trajectory exactly where the residency ended.
//!
//! **Shared-estimate approximation**: serving reuses ONE calibration
//! estimate `H ≈ J_g⁻¹` per [`ModelKey`] — the serving-side analogue of
//! SHINE's forward/backward sharing, cached as the
//! [`EstimateHandle`](crate::solvers::session::EstimateHandle) the
//! calibration probe's `SolveOutcome` captured. Requests whose Jacobian
//! drifts from the calibration point degrade toward the Jacobian-free
//! direction (Fung et al., 2021); the per-column fallback guard
//! ([`EngineConfig::fallback_ratio`], paper §3) caps the blow-up by
//! reverting any cotangent whose panel answer grows beyond `ratio · ‖dz‖`,
//! and the guard's cumulative trip rate doubles as the **staleness signal**
//! ([`RecalibPolicy`]): cross the threshold and the estimate is evicted and
//! re-calibrated (the continuous re-calibration policy the [`Router`] runs
//! per key).
//!
//! **Failure domains** (see `docs/adr/004-fault-tolerant-serving.md`): the
//! serve tier isolates faults at three scopes. *Per column* — a NaN/Inf
//! residual or cotangent answer is confined to its own column by the
//! hardened §3 guard and retired early, typed as
//! [`ServeError::ModelFault`]; neighbours in the same batch stay
//! bit-exact. *Per key* — K consecutive faulted batches open that key's
//! [`CircuitBreaker`], which serves the backward Jacobian-free (Fung et
//! al.) while the estimate rests, half-open probes, and closes on a
//! healthy batch; other keys' engines never notice. *Per shard* — a
//! panicking model residual is caught by the worker's `catch_unwind`
//! supervision: in-flight requests resolve as
//! [`ServeError::WorkerLost`] (never a hung `collect`), the dead shard's
//! queues re-home through the steal machinery, and the worker respawns
//! with bit-identical lazily-rebuilt engines. A key whose model keeps
//! panicking is **quarantined** after [`ShardConfig::quarantine_after`]
//! attributable respawns ([`QUARANTINE_STRIKES`] by default): its queued
//! and future requests resolve as typed [`ServeError::ModelFault`]
//! instead of respawn-looping the shard, and the strike/quarantine record
//! is published via [`ShardedRouter::key_metrics`]. Every submitted request
//! resolves to exactly one typed outcome ([`ShardResponse::error`]),
//! deadlines are enforced at admission and drain
//! ([`ServeError::DeadlineExceeded`]), and the whole surface is exercised
//! by the seeded [`FaultPlan`] chaos harness (`serve-bench --chaos`,
//! pinned in `rust/tests/serve_faults.rs`).
//!
//! **Session API**: the engine is a consumer of
//! [`crate::solvers::session`] — [`EngineConfig`] carries the forward and
//! calibration [`SolverSpec`](crate::solvers::session::SolverSpec)s (the
//! single source of truth for tolerances/budgets), the forward is a built
//! [`FixedPointSolver`](crate::solvers::session::FixedPointSolver) driven
//! over the block, and multi-model routing ([`ModelKey`] +
//! [`KeyedScheduler`] + [`Router`]) is per-key engines whose estimate cache
//! is keyed by model id + parameter version — a version bump invalidates
//! exactly one key.
//!
//! [`picard_solve_batch`]: crate::solvers::fixed_point::picard_solve_batch
//! [`AndersonBatch`]: crate::solvers::fixed_point::AndersonBatch
//! [`Workspace`]: crate::qn::Workspace
//! [`Workspace::take_idx`]: crate::qn::Workspace::take_idx

pub mod engine;
pub mod loadgen;
pub mod router;
pub mod scheduler;
pub mod shard;
pub mod synth;

pub use engine::{
    Admission, BatchReport, BreakerConfig, BreakerState, CircuitBreaker, EngineConfig,
    RecalibPolicy, ServeEngine, StreamReport,
};
pub use loadgen::{
    run_closed_loop, run_http_open_loop, run_open_loop, run_routed_closed_loop,
    run_sharded_open_loop, run_sharded_open_loop_with, run_suite, Arrivals, HttpLoadConfig,
    HttpReport, LoadConfig, OpenLoopConfig, OpenLoopReport, RoutedLoadConfig, RoutedReport,
    ShardedLoadConfig, ShardedReport, SuiteRow, SwapTelemetry, ThroughputReport,
};
pub use router::{BatchResidual, KeyedScheduler, ModelKey, Router};
pub use scheduler::{
    AdaptiveWidth, AdaptiveWidthConfig, ConfigError, QueueEntry, Rejected, RetryPolicy, SchedStats,
    Scheduler, SchedulerConfig,
};
pub use shard::{
    KeyMetrics, ServeError, ShardConfig, ShardRequest, ShardResponse, ShardStats, ShardedRouter,
    SharedModel, SubmitError, QUARANTINE_STRIKES, STEAL_COOLDOWN_BATCHES,
};
pub use synth::{Fault, FaultPlan, FaultyModel, SynthDeq};
