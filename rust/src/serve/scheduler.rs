//! Micro-batching admission queue: a bounded FIFO drained into batches by
//! max-batch-size / max-wait (semantics in the [`crate::serve`] contract).
//!
//! The scheduler is deliberately clock-agnostic — every operation takes
//! `now` as a parameter — so the same code runs against wall time in the
//! serving loop and against a manual clock in tests.
//!
//! Admission is part of the typed failure surface (see
//! `docs/adr/004-fault-tolerant-serving.md`): a rejected push carries a
//! [`Rejected::retry_after`] hint derived from the queue's recent drain
//! rate, entries may carry a **deadline** past which they are garbage
//! collected at drain time instead of being served, and [`SchedStats`]
//! counts every admission outcome so dropped work is visible, never silent.

use std::collections::VecDeque;
use std::fmt;

/// Typed rejection for malformed serving configuration. Constructors used
/// on CLI-reachable paths validate through `try_new`/`validate` and return
/// this instead of `assert!`-aborting the process; the panicking `new`
/// wrappers remain for in-crate callers whose configs are static.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ConfigError {
    /// `max_batch` must be at least 1.
    ZeroMaxBatch,
    /// `queue_cap` must fit at least one full batch.
    QueueCapBelowBatch { queue_cap: usize, max_batch: usize },
    /// `max_wait` must be finite and non-negative.
    BadMaxWait(f64),
    /// `min_width` must be at least 1.
    ZeroMinWidth,
    /// `max_width` must be at least `min_width`.
    WidthBoundsInverted { min_width: usize, max_width: usize },
    /// EWMA smoothing factor must lie in (0, 1].
    BadAlpha(f64),
    /// `target_latency` must be finite and positive.
    BadTargetLatency(f64),
    /// The calibration spec must be Broyden — only it captures an estimate.
    NonBroydenCalibration,
    /// `fallback_ratio` must be finite and positive.
    BadFallbackRatio(f64),
    /// `RecalibPolicy::trip_rate` must be finite and positive.
    BadTripRate(f64),
    /// `RecalibPolicy::min_cols` must be at least 1.
    ZeroMinCols,
    /// `col_budget` must be at least 1 iteration.
    ZeroColBudget,
    /// Circuit-breaker strike threshold must be at least 1.
    ZeroBreakerThreshold,
    /// A sharded router needs at least one shard.
    ZeroShards,
    /// The scheduler may not release batches wider than the engine accepts.
    SchedBatchExceedsEngine { sched_batch: usize, engine_batch: usize },
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            ConfigError::ZeroMaxBatch => write!(f, "max_batch must be at least 1"),
            ConfigError::QueueCapBelowBatch {
                queue_cap,
                max_batch,
            } => write!(
                f,
                "queue_cap {queue_cap} must fit at least one full batch (max_batch {max_batch})"
            ),
            ConfigError::BadMaxWait(v) => {
                write!(f, "max_wait must be finite and non-negative, got {v}")
            }
            ConfigError::ZeroMinWidth => write!(f, "min_width must be at least 1"),
            ConfigError::WidthBoundsInverted {
                min_width,
                max_width,
            } => write!(f, "max_width {max_width} must be at least min_width {min_width}"),
            ConfigError::BadAlpha(v) => write!(f, "alpha must be in (0, 1], got {v}"),
            ConfigError::BadTargetLatency(v) => {
                write!(f, "target_latency must be finite and positive, got {v}")
            }
            ConfigError::NonBroydenCalibration => {
                write!(f, "calibration solver must be Broyden (it captures the estimate)")
            }
            ConfigError::BadFallbackRatio(v) => {
                write!(f, "fallback_ratio must be finite and positive, got {v}")
            }
            ConfigError::BadTripRate(v) => {
                write!(f, "recalib trip_rate must be finite and positive, got {v}")
            }
            ConfigError::ZeroMinCols => write!(f, "recalib min_cols must be at least 1"),
            ConfigError::ZeroColBudget => write!(f, "col_budget must be at least 1"),
            ConfigError::ZeroBreakerThreshold => {
                write!(f, "breaker threshold must be at least 1")
            }
            ConfigError::ZeroShards => write!(f, "need at least one shard"),
            ConfigError::SchedBatchExceedsEngine {
                sched_batch,
                engine_batch,
            } => write!(
                f,
                "scheduler max_batch {sched_batch} cannot exceed engine max_batch {engine_batch}"
            ),
        }
    }
}

impl std::error::Error for ConfigError {}

#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    /// Maximum requests released as one batch (engine `max_batch`).
    pub max_batch: usize,
    /// Seconds the oldest queued request may wait before a partial batch is
    /// released anyway (the latency/throughput knob).
    pub max_wait: f64,
    /// Bounded queue capacity; pushes beyond it are rejected (backpressure).
    pub queue_cap: usize,
}

impl SchedulerConfig {
    /// Typed validation backing [`Scheduler::try_new`] (and the keyed
    /// variant) — malformed CLI input surfaces as [`ConfigError`] instead
    /// of an `assert!` abort.
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.max_batch < 1 {
            return Err(ConfigError::ZeroMaxBatch);
        }
        if self.queue_cap < self.max_batch {
            return Err(ConfigError::QueueCapBelowBatch {
                queue_cap: self.queue_cap,
                max_batch: self.max_batch,
            });
        }
        if !self.max_wait.is_finite() || self.max_wait < 0.0 {
            return Err(ConfigError::BadMaxWait(self.max_wait));
        }
        Ok(())
    }
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_batch: 32,
            max_wait: 2e-3,
            queue_cap: 1024,
        }
    }
}

/// Bounded retry-with-backoff policy for `QueueFull` admission rejections
/// — shared by every submit surface (the load drivers' open-loop
/// submitters, [`ShardedRouter::submit_with_retry`](crate::serve::ShardedRouter::submit_with_retry),
/// the HTTP front door) so callers see ONE backoff behaviour and the HTTP
/// layer can echo it (`Retry-After` / `x-shine-attempts` headers) instead
/// of each driver hand-rolling its own loop.
///
/// Retry `k` (0-based) sleeps `hint · multiplier^k` seconds, where `hint`
/// is the rejection's [`Rejected::retry_after`] drain-rate estimate —
/// exponential backoff seeded by live queue telemetry, capped at
/// `max_backoff` per sleep and `attempts` retries total.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RetryPolicy {
    /// Maximum retries after the initial attempt (0 = never retry).
    pub attempts: usize,
    /// Exponential backoff growth per retry.
    pub multiplier: f64,
    /// Cap on a single backoff sleep, seconds.
    pub max_backoff: f64,
}

impl RetryPolicy {
    /// Fail fast: a single attempt, no sleeping. What a network front end
    /// wants — the caller holds the connection, so shed in microseconds
    /// and let the client back off on the echoed `Retry-After`.
    pub fn none() -> RetryPolicy {
        RetryPolicy {
            attempts: 0,
            multiplier: 1.0,
            max_backoff: 0.0,
        }
    }

    /// The load drivers' historical policy: up to 4 retries, doubling the
    /// drain-rate hint each time, uncapped sleeps.
    pub fn standard() -> RetryPolicy {
        RetryPolicy {
            attempts: 4,
            multiplier: 2.0,
            max_backoff: f64::INFINITY,
        }
    }

    /// Backoff before retry number `attempt` (0-based count of retries
    /// already performed): `Some(seconds)` to sleep then retry, `None`
    /// when the budget is exhausted and the rejection is final.
    pub fn backoff(&self, attempt: usize, hint: f64) -> Option<f64> {
        if attempt >= self.attempts {
            return None;
        }
        let hint = if hint.is_finite() && hint > 0.0 {
            hint
        } else {
            1e-4
        };
        let delay = hint * self.multiplier.powi(attempt as i32);
        Some(delay.min(self.max_backoff).max(0.0))
    }
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy::standard()
    }
}

/// Admission telemetry for a bounded queue. `expired` counts
/// deadline-expired entries garbage-collected at drain time (each is handed
/// back through `take_expired` so the caller can publish a typed
/// `DeadlineExceeded` outcome — GC never silently drops a request).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SchedStats {
    pub accepted: usize,
    pub rejected: usize,
    pub expired: usize,
}

/// One queued request: arrival stamp, absolute deadline (`f64::INFINITY`
/// when none) and the payload. Public because whole queues migrate between
/// shards via `KeyedScheduler::take_queue` / `inject_queue`.
#[derive(Clone, Debug)]
pub struct QueueEntry<T> {
    pub at: f64,
    pub deadline: f64,
    pub item: T,
}

/// A rejected push: the payload handed back plus a backoff hint (seconds)
/// derived from the queue's recent drain rate — roughly the time for one
/// slot to free. Callers retry after the hint (see the bounded
/// exponential-backoff policy in `serve::loadgen`) or shed the request.
#[derive(Debug)]
pub struct Rejected<T> {
    pub item: T,
    pub retry_after: f64,
}

/// Bounded FIFO request queue with batch-formation policy. Generic over the
/// request payload (the serving loop uses small client ids and keeps the
/// heavy state in preallocated blocks).
#[derive(Debug)]
pub struct Scheduler<T> {
    cfg: SchedulerConfig,
    /// Oldest at the front.
    queue: VecDeque<QueueEntry<T>>,
    /// Admission telemetry.
    pub stats: SchedStats,
    /// Deadline-expired entries diverted at drain time, awaiting pickup.
    expired: Vec<(f64, T)>,
    /// Drain-rate EWMA (items/second) backing the `retry_after` hint.
    last_drain: Option<f64>,
    drain_rate: f64,
}

impl<T> Scheduler<T> {
    /// Validating constructor: malformed configs come back as
    /// [`ConfigError`] instead of aborting the process.
    pub fn try_new(cfg: SchedulerConfig) -> Result<Scheduler<T>, ConfigError> {
        cfg.validate()?;
        Ok(Scheduler {
            cfg,
            queue: VecDeque::with_capacity(cfg.queue_cap),
            stats: SchedStats::default(),
            expired: Vec::new(),
            last_drain: None,
            drain_rate: 0.0,
        })
    }

    /// Panicking wrapper over [`Scheduler::try_new`] for in-crate callers
    /// with static configs.
    pub fn new(cfg: SchedulerConfig) -> Scheduler<T> {
        Scheduler::try_new(cfg).unwrap_or_else(|e| panic!("invalid scheduler config: {e}"))
    }

    pub fn config(&self) -> &SchedulerConfig {
        &self.cfg
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Backoff hint for a rejected push: the reciprocal of the recent drain
    /// rate (≈ time for one slot to free), clamped to [1µs, 1s]; before any
    /// drain has been observed, `max_wait` (the batch-release cadence).
    pub fn retry_after(&self) -> f64 {
        if self.drain_rate > 0.0 {
            (1.0 / self.drain_rate).clamp(1e-6, 1.0)
        } else {
            self.cfg.max_wait.max(1e-6)
        }
    }

    fn note_drain(&mut self, now: f64, n: usize) {
        if n == 0 {
            return;
        }
        if let Some(prev) = self.last_drain {
            let dt = (now - prev).max(1e-9);
            let inst = n as f64 / dt;
            self.drain_rate = if self.drain_rate > 0.0 {
                0.7 * self.drain_rate + 0.3 * inst
            } else {
                inst
            };
        }
        self.last_drain = Some(now);
    }

    /// Admit a request at time `now`. Rejects when the bounded queue is
    /// full — callers shed load (or back off for
    /// [`Rejected::retry_after`]) instead of queueing unboundedly.
    pub fn push(&mut self, now: f64, item: T) -> Result<(), Rejected<T>> {
        self.push_deadline(now, f64::INFINITY, item)
    }

    /// [`Scheduler::push`] with an absolute deadline: an entry still queued
    /// when `now` passes `deadline` is GC'd at drain time (counted in
    /// [`SchedStats::expired`], handed back via [`Scheduler::take_expired`]).
    pub fn push_deadline(&mut self, now: f64, deadline: f64, item: T) -> Result<(), Rejected<T>> {
        if self.queue.len() >= self.cfg.queue_cap {
            self.stats.rejected += 1;
            return Err(Rejected {
                item,
                retry_after: self.retry_after(),
            });
        }
        self.queue.push_back(QueueEntry {
            at: now,
            deadline,
            item,
        });
        self.stats.accepted += 1;
        Ok(())
    }

    /// Number of requests releasable as one batch at time `now`:
    /// `max_batch` as soon as a full batch is queued, the whole (partial)
    /// queue once the oldest request has waited `max_wait`, 0 otherwise.
    pub fn ready(&self, now: f64) -> usize {
        let n = self.queue.len();
        if n == 0 {
            return 0;
        }
        if n >= self.cfg.max_batch {
            return self.cfg.max_batch;
        }
        let oldest = self.queue.front().expect("non-empty").at;
        if now - oldest >= self.cfg.max_wait {
            n
        } else {
            0
        }
    }

    /// Earliest time a currently-queued partial batch becomes releasable
    /// (`None` when the queue is empty or already holds a full batch — then
    /// [`Scheduler::ready`] is the authority).
    pub fn next_deadline(&self) -> Option<f64> {
        if self.queue.is_empty() || self.queue.len() >= self.cfg.max_batch {
            return None;
        }
        Some(self.queue.front().expect("non-empty").at + self.cfg.max_wait)
    }

    /// Drain up to `n` oldest requests (FIFO) into `out` as
    /// `(queue latency at now, payload)` pairs. Entries whose deadline has
    /// passed are GC'd instead: counted in [`SchedStats::expired`] and
    /// diverted to the expired buffer ([`Scheduler::take_expired`]), so the
    /// released batch may be smaller than `n`.
    pub fn drain_into(&mut self, n: usize, now: f64, out: &mut Vec<(f64, T)>) {
        let take = n.min(self.queue.len());
        for _ in 0..take {
            let e = self.queue.pop_front().expect("len checked");
            if e.deadline <= now {
                self.stats.expired += 1;
                self.expired.push((now - e.at, e.item));
            } else {
                out.push((now - e.at, e.item));
            }
        }
        self.note_drain(now, take);
    }

    /// Hand over deadline-expired entries GC'd by earlier drains as
    /// `(queue latency at GC, payload)` pairs. The caller owes each one a
    /// typed `DeadlineExceeded` outcome.
    pub fn take_expired(&mut self, out: &mut Vec<(f64, T)>) {
        out.append(&mut self.expired);
    }
}

/// Knobs of the [`AdaptiveWidth`] AIMD controller.
#[derive(Clone, Copy, Debug)]
pub struct AdaptiveWidthConfig {
    /// Floor the controller never shrinks below (≥ 1).
    pub min_width: usize,
    /// Ceiling it never grows past (≤ engine `max_batch`).
    pub max_width: usize,
    /// Per-request service-latency target in seconds: EWMA above it
    /// triggers the multiplicative decrease.
    pub target_latency: f64,
    /// EWMA smoothing factor in (0, 1]; 1 = no smoothing.
    pub alpha: f64,
}

impl AdaptiveWidthConfig {
    /// Typed validation backing [`AdaptiveWidth::try_new`].
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.min_width < 1 {
            return Err(ConfigError::ZeroMinWidth);
        }
        if self.max_width < self.min_width {
            return Err(ConfigError::WidthBoundsInverted {
                min_width: self.min_width,
                max_width: self.max_width,
            });
        }
        if !(self.alpha > 0.0 && self.alpha <= 1.0) {
            return Err(ConfigError::BadAlpha(self.alpha));
        }
        if !self.target_latency.is_finite() || self.target_latency <= 0.0 {
            return Err(ConfigError::BadTargetLatency(self.target_latency));
        }
        Ok(())
    }
}

impl Default for AdaptiveWidthConfig {
    fn default() -> Self {
        AdaptiveWidthConfig {
            min_width: 1,
            max_width: 32,
            target_latency: 5e-3,
            alpha: 0.3,
        }
    }
}

/// AIMD batch-width controller driven by per-request service latency (the
/// `BatchReport` `fwd_seconds + bwd_seconds` divided by the batch width).
/// Classic congestion-control shape: an EWMA of observed latency above
/// `target_latency` **halves** the width (fast escape when a wide block
/// makes every co-batched request slow); comfortably below target
/// (< 0.7 × target) it creeps back up by **one** column. The streaming
/// engine polls [`AdaptiveWidth::width`] each sweep via its `width`
/// closure, so the block geometry adapts mid-solve without reforming.
#[derive(Clone, Copy, Debug)]
pub struct AdaptiveWidth {
    cfg: AdaptiveWidthConfig,
    width: usize,
    ewma: Option<f64>,
}

impl AdaptiveWidth {
    /// Validating constructor; starts wide (at `max_width`): under light
    /// load width barely matters, and under heavy load the first
    /// over-target observation halves it.
    pub fn try_new(cfg: AdaptiveWidthConfig) -> Result<AdaptiveWidth, ConfigError> {
        cfg.validate()?;
        Ok(AdaptiveWidth {
            cfg,
            width: cfg.max_width,
            ewma: None,
        })
    }

    /// Panicking wrapper over [`AdaptiveWidth::try_new`] for in-crate
    /// callers with static configs.
    pub fn new(cfg: AdaptiveWidthConfig) -> AdaptiveWidth {
        AdaptiveWidth::try_new(cfg).unwrap_or_else(|e| panic!("invalid width config: {e}"))
    }

    pub fn config(&self) -> &AdaptiveWidthConfig {
        &self.cfg
    }

    /// Current admission width (always within `[min_width, max_width]`).
    pub fn width(&self) -> usize {
        self.width
    }

    /// Smoothed latency the controller is acting on (`None` before the
    /// first observation).
    pub fn ewma_latency(&self) -> Option<f64> {
        self.ewma
    }

    /// Feed one per-request service-latency observation (seconds) and
    /// update the width: multiplicative decrease above target, additive
    /// increase below 0.7 × target, hold in the comfort band between.
    /// Non-finite observations (a faulting model's NaN timings) are
    /// discarded — one poisoned sample must not wedge the EWMA forever.
    pub fn observe(&mut self, latency_s: f64) {
        if !latency_s.is_finite() {
            return;
        }
        let e = match self.ewma {
            Some(prev) => prev + self.cfg.alpha * (latency_s - prev),
            None => latency_s,
        };
        self.ewma = Some(e);
        if e > self.cfg.target_latency {
            self.width = (self.width / 2).max(self.cfg.min_width);
        } else if e < 0.7 * self.cfg.target_latency {
            self.width = (self.width + 1).min(self.cfg.max_width);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn sched(max_batch: usize, max_wait: f64, cap: usize) -> Scheduler<u32> {
        Scheduler::new(SchedulerConfig {
            max_batch,
            max_wait,
            queue_cap: cap,
        })
    }

    #[test]
    fn full_batch_releases_immediately() {
        let mut s = sched(4, 1.0, 16);
        for i in 0..3 {
            s.push(0.0, i).unwrap();
        }
        assert_eq!(s.ready(0.0), 0); // partial, no wait elapsed
        s.push(0.0, 3).unwrap();
        assert_eq!(s.ready(0.0), 4); // full batch, no waiting
        // Overfull queue still releases max_batch at a time.
        for i in 4..10 {
            s.push(0.0, i).unwrap();
        }
        assert_eq!(s.ready(0.0), 4);
    }

    #[test]
    fn partial_batch_waits_for_oldest_deadline() {
        let mut s = sched(8, 0.5, 16);
        s.push(1.0, 1).unwrap();
        s.push(1.2, 2).unwrap();
        assert_eq!(s.ready(1.4), 0);
        assert_eq!(s.next_deadline(), Some(1.5));
        assert_eq!(s.ready(1.5), 2); // oldest waited max_wait → release all
    }

    #[test]
    fn bounded_queue_rejects_with_backpressure() {
        let mut s = sched(2, 1.0, 3);
        assert!(s.push(0.0, 1).is_ok());
        assert!(s.push(0.0, 2).is_ok());
        assert!(s.push(0.0, 3).is_ok());
        let r = s.push(0.0, 4).unwrap_err();
        assert_eq!(r.item, 4);
        assert_eq!(s.stats.accepted, 3);
        assert_eq!(s.stats.rejected, 1);
        // Draining frees capacity.
        let mut out = Vec::new();
        s.drain_into(2, 0.0, &mut out);
        assert!(s.push(0.0, 4).is_ok());
    }

    #[test]
    fn rejection_carries_drain_rate_retry_hint() {
        let mut s = sched(2, 0.25, 2);
        // No drain history yet: the hint falls back to max_wait.
        s.push(0.0, 1).unwrap();
        s.push(0.0, 2).unwrap();
        let r = s.push(0.0, 3).unwrap_err();
        assert_eq!(r.retry_after, 0.25);
        // Two drains 1s apart at 2 items/drain → rate 2/s → hint 0.5s.
        let mut out = Vec::new();
        s.drain_into(2, 1.0, &mut out); // sets the baseline stamp
        s.push(1.0, 4).unwrap();
        s.push(1.0, 5).unwrap();
        out.clear();
        s.drain_into(2, 2.0, &mut out); // 2 items over 1s → 2 items/s
        s.push(2.0, 6).unwrap();
        s.push(2.0, 7).unwrap();
        let r = s.push(2.0, 8).unwrap_err();
        assert!((r.retry_after - 0.5).abs() < 1e-12, "hint {}", r.retry_after);
    }

    #[test]
    fn expired_entries_are_gcd_at_drain_and_counted() {
        let mut s = sched(4, 0.1, 16);
        s.push_deadline(0.0, 0.5, 10).unwrap(); // expires at 0.5
        s.push(0.0, 20).unwrap(); // no deadline
        s.push_deadline(0.0, 5.0, 30).unwrap(); // still live at drain
        let mut out = Vec::new();
        s.drain_into(s.ready(1.0), 1.0, &mut out);
        // The expired entry never reaches the batch…
        assert_eq!(out.iter().map(|&(_, x)| x).collect::<Vec<_>>(), vec![20, 30]);
        assert_eq!(s.stats.expired, 1);
        // …but is handed back for a typed DeadlineExceeded outcome.
        let mut exp = Vec::new();
        s.take_expired(&mut exp);
        assert_eq!(exp.len(), 1);
        assert_eq!(exp[0].1, 10);
        assert_eq!(exp[0].0, 1.0); // queue latency at GC
        let mut again = Vec::new();
        s.take_expired(&mut again);
        assert!(again.is_empty(), "expired buffer drains once");
    }

    #[test]
    fn config_rejections_are_typed() {
        let bad_batch = SchedulerConfig {
            max_batch: 0,
            ..SchedulerConfig::default()
        };
        assert_eq!(
            Scheduler::<u32>::try_new(bad_batch).err(),
            Some(ConfigError::ZeroMaxBatch)
        );
        let bad_cap = SchedulerConfig {
            max_batch: 8,
            queue_cap: 4,
            ..SchedulerConfig::default()
        };
        assert_eq!(
            Scheduler::<u32>::try_new(bad_cap).err(),
            Some(ConfigError::QueueCapBelowBatch {
                queue_cap: 4,
                max_batch: 8
            })
        );
        let bad_wait = SchedulerConfig {
            max_wait: f64::NAN,
            ..SchedulerConfig::default()
        };
        assert!(matches!(
            Scheduler::<u32>::try_new(bad_wait).err(),
            Some(ConfigError::BadMaxWait(w)) if w.is_nan()
        ));
    }

    #[test]
    fn width_config_rejections_are_typed() {
        let base = AdaptiveWidthConfig::default();
        let cases = [
            (
                AdaptiveWidthConfig {
                    min_width: 0,
                    ..base
                },
                ConfigError::ZeroMinWidth,
            ),
            (
                AdaptiveWidthConfig {
                    min_width: 8,
                    max_width: 4,
                    ..base
                },
                ConfigError::WidthBoundsInverted {
                    min_width: 8,
                    max_width: 4,
                },
            ),
            (
                AdaptiveWidthConfig { alpha: 0.0, ..base },
                ConfigError::BadAlpha(0.0),
            ),
            (
                AdaptiveWidthConfig {
                    target_latency: f64::INFINITY,
                    ..base
                },
                ConfigError::BadTargetLatency(f64::INFINITY),
            ),
        ];
        for (cfg, want) in cases {
            assert_eq!(AdaptiveWidth::try_new(cfg).err(), Some(want));
        }
    }

    #[test]
    fn drain_is_fifo_with_latency() {
        let mut s = sched(3, 1.0, 8);
        s.push(0.0, 10).unwrap();
        s.push(0.5, 20).unwrap();
        s.push(0.75, 30).unwrap();
        let mut out = Vec::new();
        s.drain_into(s.ready(0.75), 1.0, &mut out);
        assert_eq!(out, vec![(1.0, 10), (0.5, 20), (0.25, 30)]);
        assert!(s.is_empty());
        assert_eq!(s.ready(2.0), 0);
        assert_eq!(s.next_deadline(), None);
    }

    #[test]
    fn full_queue_has_no_deadline() {
        let mut s = sched(2, 1.0, 8);
        s.push(0.0, 1).unwrap();
        assert!(s.next_deadline().is_some());
        s.push(0.0, 2).unwrap();
        assert_eq!(s.next_deadline(), None); // full batch: ready now
        assert_eq!(s.ready(0.0), 2);
    }

    #[test]
    fn adaptive_width_halves_under_overload() {
        let cfg = AdaptiveWidthConfig {
            min_width: 1,
            max_width: 32,
            target_latency: 1e-3,
            alpha: 1.0, // no smoothing: each observation acts directly
        };
        let mut aw = AdaptiveWidth::new(cfg);
        assert_eq!(aw.width(), 32);
        aw.observe(5e-3); // over target → halve
        assert_eq!(aw.width(), 16);
        aw.observe(5e-3);
        aw.observe(5e-3);
        assert_eq!(aw.width(), 4);
        for _ in 0..10 {
            aw.observe(5e-3);
        }
        assert_eq!(aw.width(), 1, "multiplicative decrease floors at min");
    }

    #[test]
    fn adaptive_width_ignores_non_finite_latency() {
        let mut aw = AdaptiveWidth::new(AdaptiveWidthConfig {
            alpha: 1.0,
            ..AdaptiveWidthConfig::default()
        });
        aw.observe(1e-4);
        let (w, e) = (aw.width(), aw.ewma_latency());
        aw.observe(f64::NAN);
        aw.observe(f64::INFINITY);
        assert_eq!(aw.width(), w, "poisoned samples must not move the width");
        assert_eq!(aw.ewma_latency(), e, "poisoned samples must not wedge the EWMA");
    }

    #[test]
    fn adaptive_width_climbs_additively_when_comfortable() {
        let cfg = AdaptiveWidthConfig {
            min_width: 1,
            max_width: 8,
            target_latency: 1e-3,
            alpha: 1.0,
        };
        let mut aw = AdaptiveWidth::new(cfg);
        for _ in 0..4 {
            aw.observe(5e-3);
        }
        assert_eq!(aw.width(), 1);
        // Comfortably under target (< 0.7×): +1 per observation, capped.
        for k in 1..=10 {
            aw.observe(1e-4);
            assert_eq!(aw.width(), (1 + k).min(8));
        }
        // Comfort band (between 0.7× and 1× target): hold.
        aw.observe(0.8e-3);
        assert_eq!(aw.width(), 8);
    }

    #[test]
    fn prop_adaptive_width_stays_in_bounds() {
        // Under ARBITRARY latency sequences (heavy-tailed, bursty, zero,
        // huge) and arbitrary valid configs, the width never leaves
        // [min_width, max_width] and the EWMA stays finite.
        prop::check("adaptive width bounds", 200, |rng| {
            let min_width = 1 + rng.below(4);
            let max_width = min_width + rng.below(32);
            let cfg = AdaptiveWidthConfig {
                min_width,
                max_width,
                target_latency: rng.uniform_in(1e-6, 1e-1),
                alpha: rng.uniform_in(0.05, 1.0),
            };
            let mut aw = AdaptiveWidth::new(cfg);
            for _ in 0..200 {
                let lat = match rng.below(4) {
                    0 => 0.0,
                    1 => rng.uniform_in(0.0, 2.0 * cfg.target_latency),
                    2 => rng.exponential(1.0 / cfg.target_latency),
                    _ => rng.pareto_interarrival(cfg.target_latency, 1.5),
                };
                aw.observe(lat);
                prop::ensure(
                    (cfg.min_width..=cfg.max_width).contains(&aw.width()),
                    &format!(
                        "width {} outside [{}, {}]",
                        aw.width(),
                        cfg.min_width,
                        cfg.max_width
                    ),
                )?;
                prop::ensure(
                    aw.ewma_latency().map(|e| e.is_finite()).unwrap_or(false),
                    "EWMA must be finite after an observation",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_adaptive_width_halves_on_sustained_overload() {
        // Any sustained over-target sequence drives a geometric descent:
        // once the EWMA crosses target, every further over-target
        // observation halves the width (floored at min), so after
        // log2(max) + slack observations the width must sit at min_width.
        prop::check("adaptive width halves under overload", 100, |rng| {
            let min_width = 1 + rng.below(3);
            let max_width = (min_width + 1 + rng.below(31)).min(64);
            let cfg = AdaptiveWidthConfig {
                min_width,
                max_width,
                target_latency: rng.uniform_in(1e-5, 1e-2),
                alpha: rng.uniform_in(0.3, 1.0),
            };
            let mut aw = AdaptiveWidth::new(cfg);
            let mut prev = aw.width();
            let mut crossed = false;
            // Latencies 2×–10× target: the EWMA converges above target from
            // any start, and with alpha ≥ 0.3 it crosses within a few steps.
            for _ in 0..64 {
                let lat = cfg.target_latency * rng.uniform_in(2.0, 10.0);
                aw.observe(lat);
                let e = aw.ewma_latency().expect("observed");
                if e > cfg.target_latency {
                    crossed = true;
                    prop::ensure(
                        aw.width() == (prev / 2).max(cfg.min_width),
                        &format!("over-target step must halve: {prev} -> {}", aw.width()),
                    )?;
                }
                prev = aw.width();
            }
            prop::ensure(crossed, "EWMA never crossed target under 2-10x load")?;
            prop::ensure(
                aw.width() == cfg.min_width,
                &format!("sustained overload must floor width at {min_width}, got {prev}"),
            )?;
            Ok(())
        });
    }

    #[test]
    fn prop_adaptive_width_recovers_additively() {
        // After any overload history, comfortable latencies (< 0.7×target)
        // grow the width by EXACTLY one per observation until max_width.
        prop::check("adaptive width additive recovery", 100, |rng| {
            let min_width = 1 + rng.below(3);
            let max_width = min_width + 1 + rng.below(31);
            let cfg = AdaptiveWidthConfig {
                min_width,
                max_width,
                target_latency: rng.uniform_in(1e-5, 1e-2),
                alpha: rng.uniform_in(0.3, 1.0),
            };
            let mut aw = AdaptiveWidth::new(cfg);
            // Random overload prefix leaves the width somewhere low.
            for _ in 0..rng.below(20) {
                aw.observe(cfg.target_latency * rng.uniform_in(2.0, 8.0));
            }
            // Drive the EWMA deep into the comfort zone first (recovery
            // steps before the EWMA drops below 0.7×target are holds, not
            // increases — that lag is the AIMD hysteresis, so burn it off).
            for _ in 0..64 {
                aw.observe(cfg.target_latency * 1e-3);
                if aw.ewma_latency().expect("observed") < 0.7 * cfg.target_latency {
                    break;
                }
            }
            prop::ensure(
                aw.ewma_latency().expect("observed") < 0.7 * cfg.target_latency,
                "EWMA must reach the comfort zone under near-zero latency",
            )?;
            let start = aw.width();
            for k in 1..=(max_width + 4) {
                aw.observe(cfg.target_latency * 1e-3);
                prop::ensure(
                    aw.width() == (start + k).min(cfg.max_width),
                    &format!(
                        "recovery must be +1/observation: start {start}, step {k}, got {}",
                        aw.width()
                    ),
                )?;
            }
            prop::ensure(aw.width() == cfg.max_width, "recovery must reach max_width")?;
            Ok(())
        });
    }

    #[test]
    fn adaptive_width_ewma_smooths_spikes() {
        let cfg = AdaptiveWidthConfig {
            min_width: 1,
            max_width: 16,
            target_latency: 1e-3,
            alpha: 0.3,
        };
        let mut aw = AdaptiveWidth::new(cfg);
        aw.observe(0.5e-3); // seeds the EWMA under target
        assert_eq!(aw.width(), 16);
        // One 2× spike moves the EWMA to 0.5 + 0.3·(2−0.5) = 0.95 ms —
        // still under target, so the width holds instead of halving.
        aw.observe(2e-3);
        assert!(aw.ewma_latency().unwrap() < 1e-3);
        assert_eq!(aw.width(), 16);
    }
}
