//! Micro-batching admission queue: a bounded FIFO drained into batches by
//! max-batch-size / max-wait (semantics in the [`crate::serve`] contract).
//!
//! The scheduler is deliberately clock-agnostic — every operation takes
//! `now` as a parameter — so the same code runs against wall time in the
//! serving loop and against a manual clock in tests.

use std::collections::VecDeque;

#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    /// Maximum requests released as one batch (engine `max_batch`).
    pub max_batch: usize,
    /// Seconds the oldest queued request may wait before a partial batch is
    /// released anyway (the latency/throughput knob).
    pub max_wait: f64,
    /// Bounded queue capacity; pushes beyond it are rejected (backpressure).
    pub queue_cap: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_batch: 32,
            max_wait: 2e-3,
            queue_cap: 1024,
        }
    }
}

/// Bounded FIFO request queue with batch-formation policy. Generic over the
/// request payload (the serving loop uses small client ids and keeps the
/// heavy state in preallocated blocks).
#[derive(Debug)]
pub struct Scheduler<T> {
    cfg: SchedulerConfig,
    /// (arrival time, payload), oldest at the front.
    queue: VecDeque<(f64, T)>,
    /// Admission telemetry.
    pub accepted: usize,
    pub rejected: usize,
}

impl<T> Scheduler<T> {
    pub fn new(cfg: SchedulerConfig) -> Scheduler<T> {
        assert!(cfg.max_batch >= 1, "max_batch must be at least 1");
        assert!(
            cfg.queue_cap >= cfg.max_batch,
            "queue_cap must fit at least one full batch"
        );
        Scheduler {
            cfg,
            queue: VecDeque::with_capacity(cfg.queue_cap),
            accepted: 0,
            rejected: 0,
        }
    }

    pub fn config(&self) -> &SchedulerConfig {
        &self.cfg
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Admit a request at time `now`. Rejects (returning the payload) when
    /// the bounded queue is full — callers shed load instead of queueing
    /// unboundedly.
    pub fn push(&mut self, now: f64, item: T) -> Result<(), T> {
        if self.queue.len() >= self.cfg.queue_cap {
            self.rejected += 1;
            return Err(item);
        }
        self.queue.push_back((now, item));
        self.accepted += 1;
        Ok(())
    }

    /// Number of requests releasable as one batch at time `now`:
    /// `max_batch` as soon as a full batch is queued, the whole (partial)
    /// queue once the oldest request has waited `max_wait`, 0 otherwise.
    pub fn ready(&self, now: f64) -> usize {
        let n = self.queue.len();
        if n == 0 {
            return 0;
        }
        if n >= self.cfg.max_batch {
            return self.cfg.max_batch;
        }
        let oldest = self.queue.front().expect("non-empty").0;
        if now - oldest >= self.cfg.max_wait {
            n
        } else {
            0
        }
    }

    /// Earliest time a currently-queued partial batch becomes releasable
    /// (`None` when the queue is empty or already holds a full batch — then
    /// [`Scheduler::ready`] is the authority).
    pub fn next_deadline(&self) -> Option<f64> {
        if self.queue.is_empty() || self.queue.len() >= self.cfg.max_batch {
            return None;
        }
        Some(self.queue.front().expect("non-empty").0 + self.cfg.max_wait)
    }

    /// Drain up to `n` oldest requests (FIFO) into `out` as
    /// `(queue latency at now, payload)` pairs.
    pub fn drain_into(&mut self, n: usize, now: f64, out: &mut Vec<(f64, T)>) {
        for _ in 0..n.min(self.queue.len()) {
            let (t, item) = self.queue.pop_front().expect("len checked");
            out.push((now - t, item));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched(max_batch: usize, max_wait: f64, cap: usize) -> Scheduler<u32> {
        Scheduler::new(SchedulerConfig {
            max_batch,
            max_wait,
            queue_cap: cap,
        })
    }

    #[test]
    fn full_batch_releases_immediately() {
        let mut s = sched(4, 1.0, 16);
        for i in 0..3 {
            s.push(0.0, i).unwrap();
        }
        assert_eq!(s.ready(0.0), 0); // partial, no wait elapsed
        s.push(0.0, 3).unwrap();
        assert_eq!(s.ready(0.0), 4); // full batch, no waiting
        // Overfull queue still releases max_batch at a time.
        for i in 4..10 {
            s.push(0.0, i).unwrap();
        }
        assert_eq!(s.ready(0.0), 4);
    }

    #[test]
    fn partial_batch_waits_for_oldest_deadline() {
        let mut s = sched(8, 0.5, 16);
        s.push(1.0, 1).unwrap();
        s.push(1.2, 2).unwrap();
        assert_eq!(s.ready(1.4), 0);
        assert_eq!(s.next_deadline(), Some(1.5));
        assert_eq!(s.ready(1.5), 2); // oldest waited max_wait → release all
    }

    #[test]
    fn bounded_queue_rejects_with_backpressure() {
        let mut s = sched(2, 1.0, 3);
        assert!(s.push(0.0, 1).is_ok());
        assert!(s.push(0.0, 2).is_ok());
        assert!(s.push(0.0, 3).is_ok());
        assert_eq!(s.push(0.0, 4), Err(4));
        assert_eq!(s.accepted, 3);
        assert_eq!(s.rejected, 1);
        // Draining frees capacity.
        let mut out = Vec::new();
        s.drain_into(2, 0.0, &mut out);
        assert!(s.push(0.0, 4).is_ok());
    }

    #[test]
    fn drain_is_fifo_with_latency() {
        let mut s = sched(3, 1.0, 8);
        s.push(0.0, 10).unwrap();
        s.push(0.5, 20).unwrap();
        s.push(0.75, 30).unwrap();
        let mut out = Vec::new();
        s.drain_into(s.ready(0.75), 1.0, &mut out);
        assert_eq!(out, vec![(1.0, 10), (0.5, 20), (0.25, 30)]);
        assert!(s.is_empty());
        assert_eq!(s.ready(2.0), 0);
        assert_eq!(s.next_deadline(), None);
    }

    #[test]
    fn full_queue_has_no_deadline() {
        let mut s = sched(2, 1.0, 8);
        s.push(0.0, 1).unwrap();
        assert!(s.next_deadline().is_some());
        s.push(0.0, 2).unwrap();
        assert_eq!(s.next_deadline(), None); // full batch: ready now
        assert_eq!(s.ready(0.0), 2);
    }
}
