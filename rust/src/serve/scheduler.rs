//! Micro-batching admission queue: a bounded FIFO drained into batches by
//! max-batch-size / max-wait (semantics in the [`crate::serve`] contract).
//!
//! The scheduler is deliberately clock-agnostic — every operation takes
//! `now` as a parameter — so the same code runs against wall time in the
//! serving loop and against a manual clock in tests.

use std::collections::VecDeque;

#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    /// Maximum requests released as one batch (engine `max_batch`).
    pub max_batch: usize,
    /// Seconds the oldest queued request may wait before a partial batch is
    /// released anyway (the latency/throughput knob).
    pub max_wait: f64,
    /// Bounded queue capacity; pushes beyond it are rejected (backpressure).
    pub queue_cap: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_batch: 32,
            max_wait: 2e-3,
            queue_cap: 1024,
        }
    }
}

/// Bounded FIFO request queue with batch-formation policy. Generic over the
/// request payload (the serving loop uses small client ids and keeps the
/// heavy state in preallocated blocks).
#[derive(Debug)]
pub struct Scheduler<T> {
    cfg: SchedulerConfig,
    /// (arrival time, payload), oldest at the front.
    queue: VecDeque<(f64, T)>,
    /// Admission telemetry.
    pub accepted: usize,
    pub rejected: usize,
}

impl<T> Scheduler<T> {
    pub fn new(cfg: SchedulerConfig) -> Scheduler<T> {
        assert!(cfg.max_batch >= 1, "max_batch must be at least 1");
        assert!(
            cfg.queue_cap >= cfg.max_batch,
            "queue_cap must fit at least one full batch"
        );
        Scheduler {
            cfg,
            queue: VecDeque::with_capacity(cfg.queue_cap),
            accepted: 0,
            rejected: 0,
        }
    }

    pub fn config(&self) -> &SchedulerConfig {
        &self.cfg
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Admit a request at time `now`. Rejects (returning the payload) when
    /// the bounded queue is full — callers shed load instead of queueing
    /// unboundedly.
    pub fn push(&mut self, now: f64, item: T) -> Result<(), T> {
        if self.queue.len() >= self.cfg.queue_cap {
            self.rejected += 1;
            return Err(item);
        }
        self.queue.push_back((now, item));
        self.accepted += 1;
        Ok(())
    }

    /// Number of requests releasable as one batch at time `now`:
    /// `max_batch` as soon as a full batch is queued, the whole (partial)
    /// queue once the oldest request has waited `max_wait`, 0 otherwise.
    pub fn ready(&self, now: f64) -> usize {
        let n = self.queue.len();
        if n == 0 {
            return 0;
        }
        if n >= self.cfg.max_batch {
            return self.cfg.max_batch;
        }
        let oldest = self.queue.front().expect("non-empty").0;
        if now - oldest >= self.cfg.max_wait {
            n
        } else {
            0
        }
    }

    /// Earliest time a currently-queued partial batch becomes releasable
    /// (`None` when the queue is empty or already holds a full batch — then
    /// [`Scheduler::ready`] is the authority).
    pub fn next_deadline(&self) -> Option<f64> {
        if self.queue.is_empty() || self.queue.len() >= self.cfg.max_batch {
            return None;
        }
        Some(self.queue.front().expect("non-empty").0 + self.cfg.max_wait)
    }

    /// Drain up to `n` oldest requests (FIFO) into `out` as
    /// `(queue latency at now, payload)` pairs.
    pub fn drain_into(&mut self, n: usize, now: f64, out: &mut Vec<(f64, T)>) {
        for _ in 0..n.min(self.queue.len()) {
            let (t, item) = self.queue.pop_front().expect("len checked");
            out.push((now - t, item));
        }
    }
}

/// Knobs of the [`AdaptiveWidth`] AIMD controller.
#[derive(Clone, Copy, Debug)]
pub struct AdaptiveWidthConfig {
    /// Floor the controller never shrinks below (≥ 1).
    pub min_width: usize,
    /// Ceiling it never grows past (≤ engine `max_batch`).
    pub max_width: usize,
    /// Per-request service-latency target in seconds: EWMA above it
    /// triggers the multiplicative decrease.
    pub target_latency: f64,
    /// EWMA smoothing factor in (0, 1]; 1 = no smoothing.
    pub alpha: f64,
}

impl Default for AdaptiveWidthConfig {
    fn default() -> Self {
        AdaptiveWidthConfig {
            min_width: 1,
            max_width: 32,
            target_latency: 5e-3,
            alpha: 0.3,
        }
    }
}

/// AIMD batch-width controller driven by per-request service latency (the
/// `BatchReport` `fwd_seconds + bwd_seconds` divided by the batch width).
/// Classic congestion-control shape: an EWMA of observed latency above
/// `target_latency` **halves** the width (fast escape when a wide block
/// makes every co-batched request slow); comfortably below target
/// (< 0.7 × target) it creeps back up by **one** column. The streaming
/// engine polls [`AdaptiveWidth::width`] each sweep via its `width`
/// closure, so the block geometry adapts mid-solve without reforming.
#[derive(Clone, Copy, Debug)]
pub struct AdaptiveWidth {
    cfg: AdaptiveWidthConfig,
    width: usize,
    ewma: Option<f64>,
}

impl AdaptiveWidth {
    /// Starts wide (at `max_width`): under light load width barely matters,
    /// and under heavy load the first over-target observation halves it.
    pub fn new(cfg: AdaptiveWidthConfig) -> AdaptiveWidth {
        assert!(cfg.min_width >= 1, "min_width must be at least 1");
        assert!(
            cfg.max_width >= cfg.min_width,
            "max_width must be at least min_width"
        );
        assert!(
            cfg.alpha > 0.0 && cfg.alpha <= 1.0,
            "alpha must be in (0, 1]"
        );
        assert!(cfg.target_latency > 0.0, "target_latency must be positive");
        AdaptiveWidth {
            cfg,
            width: cfg.max_width,
            ewma: None,
        }
    }

    pub fn config(&self) -> &AdaptiveWidthConfig {
        &self.cfg
    }

    /// Current admission width (always within `[min_width, max_width]`).
    pub fn width(&self) -> usize {
        self.width
    }

    /// Smoothed latency the controller is acting on (`None` before the
    /// first observation).
    pub fn ewma_latency(&self) -> Option<f64> {
        self.ewma
    }

    /// Feed one per-request service-latency observation (seconds) and
    /// update the width: multiplicative decrease above target, additive
    /// increase below 0.7 × target, hold in the comfort band between.
    pub fn observe(&mut self, latency_s: f64) {
        let e = match self.ewma {
            Some(prev) => prev + self.cfg.alpha * (latency_s - prev),
            None => latency_s,
        };
        self.ewma = Some(e);
        if e > self.cfg.target_latency {
            self.width = (self.width / 2).max(self.cfg.min_width);
        } else if e < 0.7 * self.cfg.target_latency {
            self.width = (self.width + 1).min(self.cfg.max_width);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn sched(max_batch: usize, max_wait: f64, cap: usize) -> Scheduler<u32> {
        Scheduler::new(SchedulerConfig {
            max_batch,
            max_wait,
            queue_cap: cap,
        })
    }

    #[test]
    fn full_batch_releases_immediately() {
        let mut s = sched(4, 1.0, 16);
        for i in 0..3 {
            s.push(0.0, i).unwrap();
        }
        assert_eq!(s.ready(0.0), 0); // partial, no wait elapsed
        s.push(0.0, 3).unwrap();
        assert_eq!(s.ready(0.0), 4); // full batch, no waiting
        // Overfull queue still releases max_batch at a time.
        for i in 4..10 {
            s.push(0.0, i).unwrap();
        }
        assert_eq!(s.ready(0.0), 4);
    }

    #[test]
    fn partial_batch_waits_for_oldest_deadline() {
        let mut s = sched(8, 0.5, 16);
        s.push(1.0, 1).unwrap();
        s.push(1.2, 2).unwrap();
        assert_eq!(s.ready(1.4), 0);
        assert_eq!(s.next_deadline(), Some(1.5));
        assert_eq!(s.ready(1.5), 2); // oldest waited max_wait → release all
    }

    #[test]
    fn bounded_queue_rejects_with_backpressure() {
        let mut s = sched(2, 1.0, 3);
        assert!(s.push(0.0, 1).is_ok());
        assert!(s.push(0.0, 2).is_ok());
        assert!(s.push(0.0, 3).is_ok());
        assert_eq!(s.push(0.0, 4), Err(4));
        assert_eq!(s.accepted, 3);
        assert_eq!(s.rejected, 1);
        // Draining frees capacity.
        let mut out = Vec::new();
        s.drain_into(2, 0.0, &mut out);
        assert!(s.push(0.0, 4).is_ok());
    }

    #[test]
    fn drain_is_fifo_with_latency() {
        let mut s = sched(3, 1.0, 8);
        s.push(0.0, 10).unwrap();
        s.push(0.5, 20).unwrap();
        s.push(0.75, 30).unwrap();
        let mut out = Vec::new();
        s.drain_into(s.ready(0.75), 1.0, &mut out);
        assert_eq!(out, vec![(1.0, 10), (0.5, 20), (0.25, 30)]);
        assert!(s.is_empty());
        assert_eq!(s.ready(2.0), 0);
        assert_eq!(s.next_deadline(), None);
    }

    #[test]
    fn full_queue_has_no_deadline() {
        let mut s = sched(2, 1.0, 8);
        s.push(0.0, 1).unwrap();
        assert!(s.next_deadline().is_some());
        s.push(0.0, 2).unwrap();
        assert_eq!(s.next_deadline(), None); // full batch: ready now
        assert_eq!(s.ready(0.0), 2);
    }

    #[test]
    fn adaptive_width_halves_under_overload() {
        let cfg = AdaptiveWidthConfig {
            min_width: 1,
            max_width: 32,
            target_latency: 1e-3,
            alpha: 1.0, // no smoothing: each observation acts directly
        };
        let mut aw = AdaptiveWidth::new(cfg);
        assert_eq!(aw.width(), 32);
        aw.observe(5e-3); // over target → halve
        assert_eq!(aw.width(), 16);
        aw.observe(5e-3);
        aw.observe(5e-3);
        assert_eq!(aw.width(), 4);
        for _ in 0..10 {
            aw.observe(5e-3);
        }
        assert_eq!(aw.width(), 1, "multiplicative decrease floors at min");
    }

    #[test]
    fn adaptive_width_climbs_additively_when_comfortable() {
        let cfg = AdaptiveWidthConfig {
            min_width: 1,
            max_width: 8,
            target_latency: 1e-3,
            alpha: 1.0,
        };
        let mut aw = AdaptiveWidth::new(cfg);
        for _ in 0..4 {
            aw.observe(5e-3);
        }
        assert_eq!(aw.width(), 1);
        // Comfortably under target (< 0.7×): +1 per observation, capped.
        for k in 1..=10 {
            aw.observe(1e-4);
            assert_eq!(aw.width(), (1 + k).min(8));
        }
        // Comfort band (between 0.7× and 1× target): hold.
        aw.observe(0.8e-3);
        assert_eq!(aw.width(), 8);
    }

    #[test]
    fn prop_adaptive_width_stays_in_bounds() {
        // Under ARBITRARY latency sequences (heavy-tailed, bursty, zero,
        // huge) and arbitrary valid configs, the width never leaves
        // [min_width, max_width] and the EWMA stays finite.
        prop::check("adaptive width bounds", 200, |rng| {
            let min_width = 1 + rng.below(4);
            let max_width = min_width + rng.below(32);
            let cfg = AdaptiveWidthConfig {
                min_width,
                max_width,
                target_latency: rng.uniform_in(1e-6, 1e-1),
                alpha: rng.uniform_in(0.05, 1.0),
            };
            let mut aw = AdaptiveWidth::new(cfg);
            for _ in 0..200 {
                let lat = match rng.below(4) {
                    0 => 0.0,
                    1 => rng.uniform_in(0.0, 2.0 * cfg.target_latency),
                    2 => rng.exponential(1.0 / cfg.target_latency),
                    _ => rng.pareto_interarrival(cfg.target_latency, 1.5),
                };
                aw.observe(lat);
                prop::ensure(
                    (cfg.min_width..=cfg.max_width).contains(&aw.width()),
                    &format!(
                        "width {} outside [{}, {}]",
                        aw.width(),
                        cfg.min_width,
                        cfg.max_width
                    ),
                )?;
                prop::ensure(
                    aw.ewma_latency().map(|e| e.is_finite()).unwrap_or(false),
                    "EWMA must be finite after an observation",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_adaptive_width_halves_on_sustained_overload() {
        // Any sustained over-target sequence drives a geometric descent:
        // once the EWMA crosses target, every further over-target
        // observation halves the width (floored at min), so after
        // log2(max) + slack observations the width must sit at min_width.
        prop::check("adaptive width halves under overload", 100, |rng| {
            let min_width = 1 + rng.below(3);
            let max_width = (min_width + 1 + rng.below(31)).min(64);
            let cfg = AdaptiveWidthConfig {
                min_width,
                max_width,
                target_latency: rng.uniform_in(1e-5, 1e-2),
                alpha: rng.uniform_in(0.3, 1.0),
            };
            let mut aw = AdaptiveWidth::new(cfg);
            let mut prev = aw.width();
            let mut crossed = false;
            // Latencies 2×–10× target: the EWMA converges above target from
            // any start, and with alpha ≥ 0.3 it crosses within a few steps.
            for _ in 0..64 {
                let lat = cfg.target_latency * rng.uniform_in(2.0, 10.0);
                aw.observe(lat);
                let e = aw.ewma_latency().expect("observed");
                if e > cfg.target_latency {
                    crossed = true;
                    prop::ensure(
                        aw.width() == (prev / 2).max(cfg.min_width),
                        &format!("over-target step must halve: {prev} -> {}", aw.width()),
                    )?;
                }
                prev = aw.width();
            }
            prop::ensure(crossed, "EWMA never crossed target under 2-10x load")?;
            prop::ensure(
                aw.width() == cfg.min_width,
                &format!("sustained overload must floor width at {min_width}, got {prev}"),
            )?;
            Ok(())
        });
    }

    #[test]
    fn prop_adaptive_width_recovers_additively() {
        // After any overload history, comfortable latencies (< 0.7×target)
        // grow the width by EXACTLY one per observation until max_width.
        prop::check("adaptive width additive recovery", 100, |rng| {
            let min_width = 1 + rng.below(3);
            let max_width = min_width + 1 + rng.below(31);
            let cfg = AdaptiveWidthConfig {
                min_width,
                max_width,
                target_latency: rng.uniform_in(1e-5, 1e-2),
                alpha: rng.uniform_in(0.3, 1.0),
            };
            let mut aw = AdaptiveWidth::new(cfg);
            // Random overload prefix leaves the width somewhere low.
            for _ in 0..rng.below(20) {
                aw.observe(cfg.target_latency * rng.uniform_in(2.0, 8.0));
            }
            // Drive the EWMA deep into the comfort zone first (recovery
            // steps before the EWMA drops below 0.7×target are holds, not
            // increases — that lag is the AIMD hysteresis, so burn it off).
            for _ in 0..64 {
                aw.observe(cfg.target_latency * 1e-3);
                if aw.ewma_latency().expect("observed") < 0.7 * cfg.target_latency {
                    break;
                }
            }
            prop::ensure(
                aw.ewma_latency().expect("observed") < 0.7 * cfg.target_latency,
                "EWMA must reach the comfort zone under near-zero latency",
            )?;
            let start = aw.width();
            for k in 1..=(max_width + 4) {
                aw.observe(cfg.target_latency * 1e-3);
                prop::ensure(
                    aw.width() == (start + k).min(cfg.max_width),
                    &format!(
                        "recovery must be +1/observation: start {start}, step {k}, got {}",
                        aw.width()
                    ),
                )?;
            }
            prop::ensure(aw.width() == cfg.max_width, "recovery must reach max_width")?;
            Ok(())
        });
    }

    #[test]
    fn adaptive_width_ewma_smooths_spikes() {
        let cfg = AdaptiveWidthConfig {
            min_width: 1,
            max_width: 16,
            target_latency: 1e-3,
            alpha: 0.3,
        };
        let mut aw = AdaptiveWidth::new(cfg);
        aw.observe(0.5e-3); // seeds the EWMA under target
        assert_eq!(aw.width(), 16);
        // One 2× spike moves the EWMA to 0.5 + 0.3·(2−0.5) = 0.95 ms —
        // still under target, so the width holds instead of halving.
        aw.observe(2e-3);
        assert!(aw.ewma_latency().unwrap() < 1e-3);
        assert_eq!(aw.width(), 16);
    }
}
