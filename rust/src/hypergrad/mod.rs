//! Hypergradient strategies — the paper's contribution surface.
//!
//! Theorem 1 gives the hypergradient (with the implicit-function sign made
//! explicit; the paper's eq. (3) keeps it implicit):
//!
//! ```text
//! dL/dθ = − ∇_z L(z*)ᵀ · J_{g_θ}(z*)⁻¹ · ∂g_θ/∂θ|_{z*}
//! ```
//!
//! Every strategy reduces to choosing the *left-solve direction*
//! `w ≈ J_{g_θ}(z*)⁻ᵀ ∇_z L(z*)`, then contracting `−wᵀ ∂g/∂θ`:
//!
//! | strategy | w |
//! |---|---|
//! | `Full` (Original / HOAG)     | iterative solve of `Jᵀw = ∇L` to tol |
//! | `Full{max_iters}` (limited)  | same, truncated (Fig. E.1 baseline) |
//! | `JacobianFree` (Fung et al.) | `w = ∇L` |
//! | `Shine`                      | `w = Hᵀ∇L`, H the forward qN estimate |
//! | `ShineRefine{k}`             | k iterative steps warm-started at SHINE |
//! | `ShineFallback{ratio}`       | SHINE, guarded: fall back to JF if `‖w‖ > ratio·‖∇L‖` (§3, "fallback strategy") |
//!
//! Since the session-API redesign the strategies are *implemented* by the
//! [`crate::solvers::session::Backward`] trait family — [`Strategy`] is the
//! bi-level-flavored spec that [`Strategy::to_backward`] lowers, and
//! [`strategies::hypergrad_session`] is the entry point ([`hypergrad_ws`]
//! remains as a workspace-shim). The same trait objects serve the DEQ
//! trainer and the batch-serving tier, so "consume the forward estimate
//! handle" is one contract across all three consumers.

pub mod strategies;

pub use strategies::{hypergrad, hypergrad_session, hypergrad_ws, HypergradResult, Strategy};

use crate::qn::low_rank::LowRank;
use crate::qn::InvOp;

/// What the forward pass hands to the backward pass — the bi-level-side
/// equivalent of [`crate::solvers::session::EstimateHandle::forward`]
/// (assembled by hand here because the L-BFGS inner solver, not a
/// fixed-point session solve, produces the estimate).
pub struct ForwardArtifacts<'a> {
    /// the (approximate) root z* of g_θ
    pub z: &'a [f64],
    /// the forward inverse estimate H ≈ J⁻¹ (None ⇒ SHINE unavailable)
    pub inv: Option<&'a dyn InvOp>,
    /// low-rank factors of H for warm-starting the refine solver
    pub low_rank: Option<&'a LowRank>,
}
