//! Strategy dispatch for the hypergradient computation (see module docs of
//! [`crate::hypergrad`] for the strategy table).

use crate::hypergrad::ForwardArtifacts;
use crate::linalg::vecops::nrm2;
use crate::problems::{InnerProblem, OuterLoss};
use crate::qn::workspace::Workspace;
use crate::qn::{InvOp, MemoryPolicy};
use crate::solvers::linear::{broyden_solve_left_ws, cg_solve};

/// Backward-pass strategy. `Full` with `max_iters = usize::MAX` is the
/// Original / HOAG method; finite `max_iters` is the "limited backward"
/// baseline of Fig. E.1 / Table E.2.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Strategy {
    Full { tol: f64, max_iters: usize },
    JacobianFree,
    Shine,
    ShineRefine { iters: usize, tol: f64 },
    ShineFallback { ratio: f64 },
}

impl Strategy {
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Full { max_iters, .. } if *max_iters == usize::MAX => "original",
            Strategy::Full { .. } => "original-limited",
            Strategy::JacobianFree => "jacobian-free",
            Strategy::Shine => "shine",
            Strategy::ShineRefine { .. } => "shine-refine",
            Strategy::ShineFallback { .. } => "shine-fallback",
        }
    }
}

#[derive(Debug, Clone)]
pub struct HypergradResult {
    /// dL/dθ (θ-dimensional)
    pub grad_theta: Vec<f64>,
    /// the left-solve direction w actually used
    pub w: Vec<f64>,
    /// matrix–vector / VJP products spent in the backward pass
    pub backward_matvecs: usize,
    /// whether the fallback guard fired (§3 fallback strategy)
    pub fallback_used: bool,
}

/// Compute the hypergradient dL/dθ for the given strategy (owns a scratch
/// workspace; outer loops that call this every iteration should hold a
/// [`Workspace`] and use [`hypergrad_ws`]).
///
/// `warm_w` — previous outer iteration's w (HOAG warm-restarts the backward
/// solve, Appendix C); only used by the iterative strategies.
pub fn hypergrad(
    prob: &dyn InnerProblem,
    outer: &dyn OuterLoss,
    theta: &[f64],
    fwd: &ForwardArtifacts,
    strategy: Strategy,
    warm_w: Option<&[f64]>,
) -> HypergradResult {
    let mut ws = Workspace::new();
    hypergrad_ws(prob, outer, theta, fwd, strategy, warm_w, &mut ws)
}

/// [`hypergrad`] with a caller-provided scratch arena, threaded through the
/// SHINE apply and the iterative backward solvers.
pub fn hypergrad_ws(
    prob: &dyn InnerProblem,
    outer: &dyn OuterLoss,
    theta: &[f64],
    fwd: &ForwardArtifacts,
    strategy: Strategy,
    warm_w: Option<&[f64]>,
    ws: &mut Workspace,
) -> HypergradResult {
    let z = fwd.z;
    let grad_l = outer.grad(z);
    let mut fallback_used = false;
    let mut backward_matvecs = 0usize;

    let w: Vec<f64> = match strategy {
        Strategy::JacobianFree => grad_l.clone(),
        Strategy::Shine => {
            let inv = fwd.inv.expect("SHINE requires a forward qN estimate");
            let mut w = vec![0.0; grad_l.len()];
            inv.apply_t_into(&grad_l, &mut w, ws);
            w
        }
        Strategy::ShineFallback { ratio } => {
            let inv = fwd.inv.expect("SHINE requires a forward qN estimate");
            let mut w_shine = vec![0.0; grad_l.len()];
            inv.apply_t_into(&grad_l, &mut w_shine, ws);
            // Norm guard: the Jacobian-Free direction is ∇L itself, available
            // at no extra cost; a SHINE direction with a much larger norm is
            // the telltale sign of a bad inversion (§3).
            if nrm2(&w_shine) > ratio * nrm2(&grad_l) {
                fallback_used = true;
                grad_l.clone()
            } else {
                w_shine
            }
        }
        Strategy::Full { tol, max_iters } => {
            solve_left(
                prob, theta, z, &grad_l, warm_w, None, tol, max_iters,
                &mut backward_matvecs, ws,
            )
        }
        Strategy::ShineRefine { iters, tol } => {
            let inv = fwd.inv.expect("refine requires a forward qN estimate");
            let w0 = inv.apply_t_vec(&grad_l);
            // O(1) panel swap on a clone: the forward estimate stays intact
            // while the backward solver grows its transposed copy.
            let h_init = fwd.low_rank.map(|lr| lr.clone().into_transposed());
            solve_left(
                prob, theta, z, &grad_l, Some(&w0), h_init, tol, iters,
                &mut backward_matvecs, ws,
            )
        }
    };

    // dL/dθ = − wᵀ ∂g/∂θ
    let mut grad_theta = prob.vjp_theta(theta, z, &w);
    for v in grad_theta.iter_mut() {
        *v = -*v;
    }
    HypergradResult {
        grad_theta,
        w,
        backward_matvecs,
        fallback_used,
    }
}

/// Solve `Jᵀ w = ∇L` with the appropriate iterative solver. The problem
/// traits return owned vectors, so the adapter closures copy into the
/// solver's buffers; the solver loops themselves stay allocation-free.
#[allow(clippy::too_many_arguments)]
fn solve_left(
    prob: &dyn InnerProblem,
    theta: &[f64],
    z: &[f64],
    grad_l: &[f64],
    w0: Option<&[f64]>,
    h_init: Option<crate::qn::low_rank::LowRank>,
    tol: f64,
    max_iters: usize,
    matvecs: &mut usize,
    ws: &mut Workspace,
) -> Vec<f64> {
    let max_iters = max_iters.min(100_000);
    if prob.is_symmetric() {
        // CG on J w = ∇L (J symmetric ⇒ Jᵀ = J), as HOAG does. The bi-level
        // stack instantiates the precision-generic solvers at E = f64 (the
        // DEQ trainer runs the same code at f32).
        let res = cg_solve(
            |v: &[f64], out: &mut [f64]| out.copy_from_slice(&prob.jvp(theta, z, v)),
            grad_l,
            w0,
            tol,
            max_iters,
        );
        *matvecs += res.n_matvecs;
        res.x
    } else {
        let res = broyden_solve_left_ws(
            |w: &[f64], out: &mut [f64]| out.copy_from_slice(&prob.vjp(theta, z, w)),
            grad_l,
            w0,
            h_init.map(|h| h.with_max_mem(max_iters + 64, MemoryPolicy::Freeze)),
            tol,
            max_iters,
            max_iters + 64,
            ws,
        );
        *matvecs += res.n_matvecs;
        res.x
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hypergrad::ForwardArtifacts;
    use crate::problems::quadratic::{QuadraticBilevel, QuadraticOuter};
    use crate::qn::InvOp;
    use crate::solvers::minimize::{lbfgs_minimize, MinimizeOptions};
    use crate::util::prop;

    /// Shared fixture: solve the inner quadratic with LBFGS to high
    /// precision, return (problem, outer, theta, result).
    fn solved_quadratic(
        rng: &mut crate::util::rng::Rng,
        n: usize,
        memory: usize,
    ) -> (
        QuadraticBilevel,
        QuadraticOuter,
        [f64; 1],
        crate::solvers::minimize::MinimizeResult,
    ) {
        let p = QuadraticBilevel::random(n, rng);
        let outer = QuadraticOuter {
            target: p.target.clone(),
        };
        let theta = [rng.normal() * 0.3];
        let obj = (n, |z: &[f64]| {
            (p.inner_value(&theta, z).unwrap(), p.g(&theta, z))
        });
        let opts = MinimizeOptions {
            tol: 1e-9,
            max_iters: 200 * n,
            memory,
            scale_gamma: false, // B₀ = I: the paper's theoretical setting
            ..Default::default()
        };
        let res = lbfgs_minimize(&obj, &vec![0.0; n], &opts, None, None);
        // Floating-point stalls just above tol are fine for these tests.
        assert!(res.grad_norm < 1e-6, "inner solve too inexact: {}", res.grad_norm);
        (p, outer, theta, res)
    }

    use crate::problems::InnerProblem;

    #[test]
    fn full_matches_exact_hypergrad() {
        prop::check("hg-full-exact", 10, |rng| {
            let (p, outer, theta, res) = solved_quadratic(rng, 8, 64);
            let fwd = ForwardArtifacts {
                z: &res.z,
                inv: Some(&res.qn),
                low_rank: None,
            };
            let hg = hypergrad(
                &p,
                &outer,
                &theta,
                &fwd,
                Strategy::Full {
                    tol: 1e-12,
                    max_iters: usize::MAX,
                },
                None,
            );
            prop::ensure_close(hg.grad_theta[0], p.exact_hypergrad(&theta), 1e-6, "full vs exact")
        });
    }

    #[test]
    fn shine_approximates_exact_with_full_memory() {
        // On a quadratic solved to convergence with memory ≥ many steps, the
        // BFGS estimate captures the Hessian in all visited directions and
        // SHINE is close to the exact hypergradient.
        prop::check("hg-shine-approx", 10, |rng| {
            let (p, outer, theta, res) = solved_quadratic(rng, 8, 256);
            let fwd = ForwardArtifacts {
                z: &res.z,
                inv: Some(&res.qn),
                low_rank: None,
            };
            let hg = hypergrad(&p, &outer, &theta, &fwd, Strategy::Shine, None);
            let exact = p.exact_hypergrad(&theta);
            // SHINE is an approximation (ULI does not hold in practice — §2.2);
            // on a well-solved quadratic it lands within ~15% and must at
            // least agree in sign (a descent direction).
            prop::ensure(
                hg.grad_theta[0] * exact > 0.0,
                &format!("sign flip: {} vs {}", hg.grad_theta[0], exact),
            )?;
            prop::ensure_close(hg.grad_theta[0], exact, 0.15, "shine vs exact")
        });
    }

    #[test]
    fn shine_never_does_backward_matvecs() {
        let mut rng = crate::util::rng::Rng::new(2);
        let (p, outer, theta, res) = solved_quadratic(&mut rng, 6, 64);
        let fwd = ForwardArtifacts {
            z: &res.z,
            inv: Some(&res.qn),
            low_rank: None,
        };
        let hg = hypergrad(&p, &outer, &theta, &fwd, Strategy::Shine, None);
        assert_eq!(hg.backward_matvecs, 0);
        let hg_jf = hypergrad(&p, &outer, &theta, &fwd, Strategy::JacobianFree, None);
        assert_eq!(hg_jf.backward_matvecs, 0);
        let hg_full = hypergrad(
            &p,
            &outer,
            &theta,
            &fwd,
            Strategy::Full {
                tol: 1e-10,
                max_iters: usize::MAX,
            },
            None,
        );
        assert!(hg_full.backward_matvecs > 0);
    }

    #[test]
    fn refine_improves_on_shine() {
        prop::check("hg-refine", 10, |rng| {
            // Small memory so vanilla SHINE is inexact.
            let (p, outer, theta, res) = solved_quadratic(rng, 12, 4);
            let fwd = ForwardArtifacts {
                z: &res.z,
                inv: Some(&res.qn),
                low_rank: None,
            };
            let exact = p.exact_hypergrad(&theta);
            let e_shine =
                (hypergrad(&p, &outer, &theta, &fwd, Strategy::Shine, None).grad_theta[0] - exact)
                    .abs();
            let e_refine = (hypergrad(
                &p,
                &outer,
                &theta,
                &fwd,
                Strategy::ShineRefine {
                    iters: 30,
                    tol: 1e-12,
                },
                None,
            )
            .grad_theta[0]
                - exact)
                .abs();
            prop::ensure(
                e_refine <= e_shine + 1e-12,
                &format!("refine {e_refine:.3e} vs shine {e_shine:.3e}"),
            )
        });
    }

    #[test]
    fn refine_with_infinite_budget_equals_full() {
        let mut rng = crate::util::rng::Rng::new(5);
        let (p, outer, theta, res) = solved_quadratic(&mut rng, 10, 8);
        let fwd = ForwardArtifacts {
            z: &res.z,
            inv: Some(&res.qn),
            low_rank: None,
        };
        let full = hypergrad(
            &p,
            &outer,
            &theta,
            &fwd,
            Strategy::Full {
                tol: 1e-12,
                max_iters: usize::MAX,
            },
            None,
        );
        let refine = hypergrad(
            &p,
            &outer,
            &theta,
            &fwd,
            Strategy::ShineRefine {
                iters: 100_000,
                tol: 1e-12,
            },
            None,
        );
        assert!((full.grad_theta[0] - refine.grad_theta[0]).abs() < 1e-8);
    }

    #[test]
    fn fallback_guard_fires_on_blown_up_inverse() {
        let mut rng = crate::util::rng::Rng::new(7);
        let (p, outer, theta, res) = solved_quadratic(&mut rng, 6, 64);
        // An adversarial inverse estimate with a huge norm.
        struct Blown(usize);
        impl InvOp for Blown {
            fn dim(&self) -> usize {
                self.0
            }
            fn apply(&self, x: &[f64], out: &mut [f64]) {
                for (o, v) in out.iter_mut().zip(x) {
                    *o = 1e6 * v;
                }
            }
            fn apply_t(&self, x: &[f64], out: &mut [f64]) {
                self.apply(x, out)
            }
        }
        let blown = Blown(6);
        let fwd = ForwardArtifacts {
            z: &res.z,
            inv: Some(&blown),
            low_rank: None,
        };
        let hg = hypergrad(
            &p,
            &outer,
            &theta,
            &fwd,
            Strategy::ShineFallback { ratio: 1.3 },
            None,
        );
        assert!(hg.fallback_used);
        // Direction must equal the Jacobian-Free one.
        let jf = hypergrad(&p, &outer, &theta, &fwd, Strategy::JacobianFree, None);
        assert_eq!(hg.grad_theta, jf.grad_theta);
    }

    #[test]
    fn fallback_keeps_shine_when_norm_ok() {
        let mut rng = crate::util::rng::Rng::new(11);
        let (p, outer, theta, res) = solved_quadratic(&mut rng, 6, 64);
        let fwd = ForwardArtifacts {
            z: &res.z,
            inv: Some(&res.qn),
            low_rank: None,
        };
        let fb = hypergrad(
            &p,
            &outer,
            &theta,
            &fwd,
            // Generous ratio: SHINE's direction norm is moderate here.
            Strategy::ShineFallback { ratio: 1e3 },
            None,
        );
        let shine = hypergrad(&p, &outer, &theta, &fwd, Strategy::Shine, None);
        assert!(!fb.fallback_used);
        assert_eq!(fb.grad_theta, shine.grad_theta);
    }

    #[test]
    fn limited_backward_degrades_gracefully() {
        // Truncating the inversion (Fig. E.1's HOAG-limited) gives a less
        // accurate hypergradient than the full solve.
        let mut rng = crate::util::rng::Rng::new(13);
        let (p, outer, theta, res) = solved_quadratic(&mut rng, 16, 4);
        let fwd = ForwardArtifacts {
            z: &res.z,
            inv: Some(&res.qn),
            low_rank: None,
        };
        let exact = p.exact_hypergrad(&theta);
        let e_full = (hypergrad(
            &p,
            &outer,
            &theta,
            &fwd,
            Strategy::Full {
                tol: 1e-12,
                max_iters: usize::MAX,
            },
            None,
        )
        .grad_theta[0]
            - exact)
            .abs();
        let e_lim = (hypergrad(
            &p,
            &outer,
            &theta,
            &fwd,
            Strategy::Full {
                tol: 1e-12,
                max_iters: 2,
            },
            None,
        )
        .grad_theta[0]
            - exact)
            .abs();
        assert!(e_full <= e_lim + 1e-12, "full {e_full:.2e} limited {e_lim:.2e}");
    }
}
