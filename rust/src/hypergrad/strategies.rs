//! Strategy dispatch for the hypergradient computation (see module docs of
//! [`crate::hypergrad`] for the strategy table).
//!
//! Since the session-API redesign, [`Strategy`] is a *spec*: the dispatch
//! lowers it to a [`crate::solvers::session::Backward`] trait object (the
//! type-level "consume the forward estimate handle" contract shared with
//! the DEQ trainer and the serving tier) and every strategy runs through
//! [`Backward::direction`]. [`hypergrad_ws`] survives as a thin shim that
//! lifts the caller's workspace into a [`Session`] and delegates to
//! [`hypergrad_session`].

use crate::hypergrad::ForwardArtifacts;
use crate::problems::{InnerProblem, OuterLoss};
use crate::qn::workspace::Workspace;
use crate::solvers::session::{
    Backward, BackwardSpec, FallbackBackward, ForwardHandle, FullBackward, JacobianFreeBackward,
    RefineBackward, RefineSeed, Session, ShineBackward,
};

/// Backward-pass strategy. `Full` with `max_iters = usize::MAX` is the
/// Original / HOAG method; finite `max_iters` is the "limited backward"
/// baseline of Fig. E.1 / Table E.2.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Strategy {
    Full { tol: f64, max_iters: usize },
    JacobianFree,
    Shine,
    ShineRefine { iters: usize, tol: f64 },
    ShineFallback { ratio: f64 },
}

impl Strategy {
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Full { max_iters, .. } if *max_iters == usize::MAX => "original",
            Strategy::Full { .. } => "original-limited",
            Strategy::JacobianFree => "jacobian-free",
            Strategy::Shine => "shine",
            Strategy::ShineRefine { .. } => "shine-refine",
            Strategy::ShineFallback { .. } => "shine-fallback",
        }
    }

    /// Lift a CLI-level [`BackwardSpec`] into this module's strategy with
    /// the bi-level stack's historical tolerance conventions.
    pub fn from_spec(spec: &BackwardSpec) -> Strategy {
        match *spec {
            BackwardSpec::JacobianFree => Strategy::JacobianFree,
            BackwardSpec::Shine => Strategy::Shine,
            BackwardSpec::ShineFallback { ratio } => Strategy::ShineFallback { ratio },
            BackwardSpec::ShineRefine { iters } => Strategy::ShineRefine { iters, tol: 1e-10 },
            BackwardSpec::Full { tol, max_iters } => Strategy::Full { tol, max_iters },
        }
    }

    /// Lower to the [`Backward`] trait object that implements this
    /// strategy. Iterative-solve budgets are capped and the backward qN
    /// memory follows the stack's historical `max_iters + 64` convention;
    /// `symmetric` problems (the inner Hessian) run CG as in HOAG.
    pub fn to_backward(self, symmetric: bool) -> Box<dyn Backward<f64>> {
        match self {
            Strategy::JacobianFree => Box::new(JacobianFreeBackward),
            Strategy::Shine => Box::new(ShineBackward),
            Strategy::ShineFallback { ratio } => Box::new(FallbackBackward { ratio }),
            Strategy::Full { tol, max_iters } => {
                let mi = max_iters.min(100_000);
                Box::new(FullBackward {
                    tol,
                    max_iters: mi,
                    max_mem: mi + 64,
                    symmetric,
                })
            }
            Strategy::ShineRefine { iters, tol } => {
                let mi = iters.min(100_000);
                Box::new(RefineBackward {
                    iters: mi,
                    tol,
                    max_mem: mi + 64,
                    seed: RefineSeed::Estimate,
                    symmetric,
                })
            }
        }
    }
}

#[derive(Debug, Clone)]
pub struct HypergradResult {
    /// dL/dθ (θ-dimensional)
    pub grad_theta: Vec<f64>,
    /// the left-solve direction w actually used
    pub w: Vec<f64>,
    /// matrix–vector / VJP products spent in the backward pass
    pub backward_matvecs: usize,
    /// whether the fallback guard fired (§3 fallback strategy)
    pub fallback_used: bool,
}

/// Compute the hypergradient dL/dθ for the given strategy (owns a scratch
/// session; outer loops that call this every iteration should hold a
/// [`Session`] and use [`hypergrad_session`]).
///
/// `warm_w` — previous outer iteration's w (HOAG warm-restarts the backward
/// solve, Appendix C); only used by the iterative strategies.
pub fn hypergrad(
    prob: &dyn InnerProblem,
    outer: &dyn OuterLoss,
    theta: &[f64],
    fwd: &ForwardArtifacts,
    strategy: Strategy,
    warm_w: Option<&[f64]>,
) -> HypergradResult {
    let mut sess = Session::new();
    hypergrad_session(prob, outer, theta, fwd, strategy, warm_w, &mut sess)
}

/// **Deprecated shim**: [`hypergrad_session`] with the scratch arena passed
/// as a raw [`Workspace`] — lifts it into a [`Session`] for the call.
pub fn hypergrad_ws(
    prob: &dyn InnerProblem,
    outer: &dyn OuterLoss,
    theta: &[f64],
    fwd: &ForwardArtifacts,
    strategy: Strategy,
    warm_w: Option<&[f64]>,
    ws: &mut Workspace,
) -> HypergradResult {
    let mut sess = Session::from_workspace(std::mem::take(ws));
    let out = hypergrad_session(prob, outer, theta, fwd, strategy, warm_w, &mut sess);
    *ws = sess.into_workspace();
    out
}

/// [`hypergrad`] with a caller-provided session: lowers the strategy to its
/// [`Backward`] trait object, runs [`Backward::direction`] against the
/// forward artifacts (the estimate handle + optional low-rank factors),
/// then contracts `dL/dθ = −wᵀ ∂g/∂θ`.
pub fn hypergrad_session(
    prob: &dyn InnerProblem,
    outer: &dyn OuterLoss,
    theta: &[f64],
    fwd: &ForwardArtifacts,
    strategy: Strategy,
    warm_w: Option<&[f64]>,
    sess: &mut Session,
) -> HypergradResult {
    let z = fwd.z;
    let grad_l = outer.grad(z);
    let symmetric = prob.is_symmetric();
    // VJP oracle for the iterative strategies. For symmetric J (the inner
    // Hessian) the oracle is the JVP — Jᵀ = J — and the Backward impls run
    // CG on it, exactly as HOAG does. The problem traits return owned
    // vectors, so the adapter copies into the solver's buffer; the solver
    // loops themselves stay allocation-free.
    let mut vjp = |w: &[f64], out: &mut [f64]| {
        if symmetric {
            out.copy_from_slice(&prob.jvp(theta, z, w));
        } else {
            out.copy_from_slice(&prob.vjp(theta, z, w));
        }
    };
    let handle = ForwardHandle {
        inv: fwd.inv,
        low_rank: fwd.low_rank,
    };
    let mut backward = strategy.to_backward(symmetric);
    let out = backward.direction(sess, handle, &grad_l, &mut vjp, warm_w);

    // dL/dθ = − wᵀ ∂g/∂θ
    let mut grad_theta = prob.vjp_theta(theta, z, &out.w);
    for v in grad_theta.iter_mut() {
        *v = -*v;
    }
    HypergradResult {
        grad_theta,
        w: out.w,
        backward_matvecs: out.matvecs,
        fallback_used: out.fallback_used,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hypergrad::ForwardArtifacts;
    use crate::problems::quadratic::{QuadraticBilevel, QuadraticOuter};
    use crate::qn::InvOp;
    use crate::solvers::minimize::{lbfgs_minimize, MinimizeOptions};
    use crate::util::prop;

    /// Shared fixture: solve the inner quadratic with LBFGS to high
    /// precision, return (problem, outer, theta, result).
    fn solved_quadratic(
        rng: &mut crate::util::rng::Rng,
        n: usize,
        memory: usize,
    ) -> (
        QuadraticBilevel,
        QuadraticOuter,
        [f64; 1],
        crate::solvers::minimize::MinimizeResult,
    ) {
        let p = QuadraticBilevel::random(n, rng);
        let outer = QuadraticOuter {
            target: p.target.clone(),
        };
        let theta = [rng.normal() * 0.3];
        let obj = (n, |z: &[f64]| {
            (p.inner_value(&theta, z).unwrap(), p.g(&theta, z))
        });
        let opts = MinimizeOptions {
            tol: 1e-9,
            max_iters: 200 * n,
            memory,
            scale_gamma: false, // B₀ = I: the paper's theoretical setting
            ..Default::default()
        };
        let res = lbfgs_minimize(&obj, &vec![0.0; n], &opts, None, None);
        // Floating-point stalls just above tol are fine for these tests.
        assert!(res.grad_norm < 1e-6, "inner solve too inexact: {}", res.grad_norm);
        (p, outer, theta, res)
    }

    use crate::problems::InnerProblem;

    #[test]
    fn full_matches_exact_hypergrad() {
        prop::check("hg-full-exact", 10, |rng| {
            let (p, outer, theta, res) = solved_quadratic(rng, 8, 64);
            let fwd = ForwardArtifacts {
                z: &res.z,
                inv: Some(&res.qn),
                low_rank: None,
            };
            let hg = hypergrad(
                &p,
                &outer,
                &theta,
                &fwd,
                Strategy::Full {
                    tol: 1e-12,
                    max_iters: usize::MAX,
                },
                None,
            );
            prop::ensure_close(hg.grad_theta[0], p.exact_hypergrad(&theta), 1e-6, "full vs exact")
        });
    }

    #[test]
    fn shine_approximates_exact_with_full_memory() {
        // On a quadratic solved to convergence with memory ≥ many steps, the
        // BFGS estimate captures the Hessian in all visited directions and
        // SHINE is close to the exact hypergradient.
        prop::check("hg-shine-approx", 10, |rng| {
            let (p, outer, theta, res) = solved_quadratic(rng, 8, 256);
            let fwd = ForwardArtifacts {
                z: &res.z,
                inv: Some(&res.qn),
                low_rank: None,
            };
            let hg = hypergrad(&p, &outer, &theta, &fwd, Strategy::Shine, None);
            let exact = p.exact_hypergrad(&theta);
            // SHINE is an approximation (ULI does not hold in practice — §2.2);
            // on a well-solved quadratic it lands within ~15% and must at
            // least agree in sign (a descent direction).
            prop::ensure(
                hg.grad_theta[0] * exact > 0.0,
                &format!("sign flip: {} vs {}", hg.grad_theta[0], exact),
            )?;
            prop::ensure_close(hg.grad_theta[0], exact, 0.15, "shine vs exact")
        });
    }

    #[test]
    fn shine_never_does_backward_matvecs() {
        let mut rng = crate::util::rng::Rng::new(2);
        let (p, outer, theta, res) = solved_quadratic(&mut rng, 6, 64);
        let fwd = ForwardArtifacts {
            z: &res.z,
            inv: Some(&res.qn),
            low_rank: None,
        };
        let hg = hypergrad(&p, &outer, &theta, &fwd, Strategy::Shine, None);
        assert_eq!(hg.backward_matvecs, 0);
        let hg_jf = hypergrad(&p, &outer, &theta, &fwd, Strategy::JacobianFree, None);
        assert_eq!(hg_jf.backward_matvecs, 0);
        let hg_full = hypergrad(
            &p,
            &outer,
            &theta,
            &fwd,
            Strategy::Full {
                tol: 1e-10,
                max_iters: usize::MAX,
            },
            None,
        );
        assert!(hg_full.backward_matvecs > 0);
    }

    #[test]
    fn refine_improves_on_shine() {
        prop::check("hg-refine", 10, |rng| {
            // Small memory so vanilla SHINE is inexact.
            let (p, outer, theta, res) = solved_quadratic(rng, 12, 4);
            let fwd = ForwardArtifacts {
                z: &res.z,
                inv: Some(&res.qn),
                low_rank: None,
            };
            let exact = p.exact_hypergrad(&theta);
            let e_shine =
                (hypergrad(&p, &outer, &theta, &fwd, Strategy::Shine, None).grad_theta[0] - exact)
                    .abs();
            let e_refine = (hypergrad(
                &p,
                &outer,
                &theta,
                &fwd,
                Strategy::ShineRefine {
                    iters: 30,
                    tol: 1e-12,
                },
                None,
            )
            .grad_theta[0]
                - exact)
                .abs();
            prop::ensure(
                e_refine <= e_shine + 1e-12,
                &format!("refine {e_refine:.3e} vs shine {e_shine:.3e}"),
            )
        });
    }

    #[test]
    fn refine_with_infinite_budget_equals_full() {
        let mut rng = crate::util::rng::Rng::new(5);
        let (p, outer, theta, res) = solved_quadratic(&mut rng, 10, 8);
        let fwd = ForwardArtifacts {
            z: &res.z,
            inv: Some(&res.qn),
            low_rank: None,
        };
        let full = hypergrad(
            &p,
            &outer,
            &theta,
            &fwd,
            Strategy::Full {
                tol: 1e-12,
                max_iters: usize::MAX,
            },
            None,
        );
        let refine = hypergrad(
            &p,
            &outer,
            &theta,
            &fwd,
            Strategy::ShineRefine {
                iters: 100_000,
                tol: 1e-12,
            },
            None,
        );
        assert!((full.grad_theta[0] - refine.grad_theta[0]).abs() < 1e-8);
    }

    #[test]
    fn fallback_guard_fires_on_blown_up_inverse() {
        let mut rng = crate::util::rng::Rng::new(7);
        let (p, outer, theta, res) = solved_quadratic(&mut rng, 6, 64);
        // An adversarial inverse estimate with a huge norm.
        struct Blown(usize);
        impl InvOp for Blown {
            fn dim(&self) -> usize {
                self.0
            }
            fn apply(&self, x: &[f64], out: &mut [f64]) {
                for (o, v) in out.iter_mut().zip(x) {
                    *o = 1e6 * v;
                }
            }
            fn apply_t(&self, x: &[f64], out: &mut [f64]) {
                self.apply(x, out)
            }
        }
        let blown = Blown(6);
        let fwd = ForwardArtifacts {
            z: &res.z,
            inv: Some(&blown),
            low_rank: None,
        };
        let hg = hypergrad(
            &p,
            &outer,
            &theta,
            &fwd,
            Strategy::ShineFallback { ratio: 1.3 },
            None,
        );
        assert!(hg.fallback_used);
        // Direction must equal the Jacobian-Free one.
        let jf = hypergrad(&p, &outer, &theta, &fwd, Strategy::JacobianFree, None);
        assert_eq!(hg.grad_theta, jf.grad_theta);
    }

    #[test]
    fn fallback_keeps_shine_when_norm_ok() {
        let mut rng = crate::util::rng::Rng::new(11);
        let (p, outer, theta, res) = solved_quadratic(&mut rng, 6, 64);
        let fwd = ForwardArtifacts {
            z: &res.z,
            inv: Some(&res.qn),
            low_rank: None,
        };
        let fb = hypergrad(
            &p,
            &outer,
            &theta,
            &fwd,
            // Generous ratio: SHINE's direction norm is moderate here.
            Strategy::ShineFallback { ratio: 1e3 },
            None,
        );
        let shine = hypergrad(&p, &outer, &theta, &fwd, Strategy::Shine, None);
        assert!(!fb.fallback_used);
        assert_eq!(fb.grad_theta, shine.grad_theta);
    }

    #[test]
    fn limited_backward_degrades_gracefully() {
        // Truncating the inversion (Fig. E.1's HOAG-limited) gives a less
        // accurate hypergradient than the full solve.
        let mut rng = crate::util::rng::Rng::new(13);
        let (p, outer, theta, res) = solved_quadratic(&mut rng, 16, 4);
        let fwd = ForwardArtifacts {
            z: &res.z,
            inv: Some(&res.qn),
            low_rank: None,
        };
        let exact = p.exact_hypergrad(&theta);
        let e_full = (hypergrad(
            &p,
            &outer,
            &theta,
            &fwd,
            Strategy::Full {
                tol: 1e-12,
                max_iters: usize::MAX,
            },
            None,
        )
        .grad_theta[0]
            - exact)
            .abs();
        let e_lim = (hypergrad(
            &p,
            &outer,
            &theta,
            &fwd,
            Strategy::Full {
                tol: 1e-12,
                max_iters: 2,
            },
            None,
        )
        .grad_theta[0]
            - exact)
            .abs();
        assert!(e_full <= e_lim + 1e-12, "full {e_full:.2e} limited {e_lim:.2e}");
    }
}
