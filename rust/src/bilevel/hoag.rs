//! HOAG-style inexact hypergradient descent (Pedregosa 2016), the outer
//! loop shared by every method in Fig. 1 / 2-left / E.1 / E.2.
//!
//! Each outer iteration k:
//! 1. solve the inner problem from the previous solution (warm restart)
//!    to tolerance ε_k = max(ε₀ · qᵏ, ε_min) — q is the paper's
//!    "exponential decrease" (0.99 for HOAG, 0.78 for accelerated methods,
//!    Appendix C);
//! 2. compute the hypergradient with the configured [`Strategy`]
//!    (backward tolerance tied to ε_k, warm-restarted w — Appendix C
//!    "warm restart is used for both the inner problem and the Hessian
//!    inversion");
//! 3. take a gradient step on θ with an adaptive step size (halve on
//!    validation-loss increase, gently grow otherwise).
//!
//! The trace records wall-clock time and held-out test loss after every
//! outer iteration — the paper's figures plot exactly this.

use crate::hypergrad::{hypergrad_session, ForwardArtifacts, Strategy};
use crate::problems::{InnerProblem, OuterLoss};
use crate::qn::lbfgs::OpaConfig;
use crate::solvers::minimize::{lbfgs_minimize, MinimizeOptions, OpaHooks};
use crate::solvers::session::Session;
use crate::util::timer::Stopwatch;

#[derive(Clone, Debug)]
pub struct HoagOptions {
    pub outer_iters: usize,
    /// initial outer step size on θ
    pub step_size: f64,
    /// initial inner tolerance ε₀
    pub tol0: f64,
    /// geometric tolerance decrease q (HOAG: 0.99; accelerated: 0.78)
    pub tol_decrease: f64,
    pub tol_min: f64,
    /// L-BFGS memory (HOAG: 10; SHINE/JF: 30; OPA: 60 — Appendix C)
    pub inner_memory: usize,
    pub inner_max_iters: usize,
    /// OPA extra updates on the inner solver (SHINE-OPA variant)
    pub opa: Option<OpaConfig>,
    pub strategy: Strategy,
    /// adapt step size on validation-loss feedback
    pub adaptive_step: bool,
    /// wall-clock budget in seconds (trace stops after exceeding it)
    pub time_budget: f64,
}

impl Default for HoagOptions {
    fn default() -> Self {
        HoagOptions {
            outer_iters: 50,
            step_size: 1.0,
            tol0: 1e-2,
            tol_decrease: 0.99,
            tol_min: 1e-10,
            inner_memory: 30,
            inner_max_iters: 2000,
            opa: None,
            strategy: Strategy::Shine,
            adaptive_step: true,
            time_budget: f64::INFINITY,
        }
    }
}

/// One outer-iteration sample of the optimization trajectory.
#[derive(Clone, Debug)]
pub struct OuterPoint {
    pub k: usize,
    pub time: f64,
    pub theta: Vec<f64>,
    pub val_loss: f64,
    pub test_loss: f64,
    pub inner_iters: usize,
    pub inner_evals: usize,
    pub backward_matvecs: usize,
    pub hypergrad_norm: f64,
    pub fallback_used: bool,
}

#[derive(Debug)]
pub struct HoagResult {
    pub theta: Vec<f64>,
    pub z: Vec<f64>,
    pub trace: Vec<OuterPoint>,
    pub total_time: f64,
}

/// Run hypergradient descent. Only scalar θ problems are exercised by the
/// paper's HPO experiments, but the loop is dimension-agnostic.
pub fn hoag_run(
    prob: &dyn InnerProblem,
    outer: &dyn OuterLoss,
    theta0: &[f64],
    opts: &HoagOptions,
) -> HoagResult {
    let sw = Stopwatch::start();
    let d = prob.dim();
    let mut theta = theta0.to_vec();
    let mut z = vec![0.0; d];
    let mut step = opts.step_size;
    let mut prev_val = f64::INFINITY;
    let mut warm_w: Option<Vec<f64>> = None;
    let mut trace = Vec::new();
    // One solve session for every backward pass of the run (Appendix C warm
    // restarts make consecutive backward solves the same size, so the
    // session's pooled buffers are reused across outer iterations).
    let mut sess = Session::new();

    for k in 0..opts.outer_iters {
        if sw.elapsed() > opts.time_budget {
            break;
        }
        let tol_k = (opts.tol0 * opts.tol_decrease.powi(k as i32)).max(opts.tol_min);

        // ---- inner solve (forward pass), warm-restarted
        let theta_k = theta.clone();
        let obj = (d, |zz: &[f64]| {
            let g = prob.g(&theta_k, zz);
            let v = prob
                .inner_value(&theta_k, zz)
                .unwrap_or_else(|| 0.5 * crate::linalg::vecops::dot(&g, &g));
            (v, g)
        });
        let min_opts = MinimizeOptions {
            tol: tol_k,
            max_iters: opts.inner_max_iters,
            memory: opts.inner_memory,
            // γ-scaling of H₀ (classical L-BFGS). Theorem 3 allows any SPD
            // B₀; without the scaling the inner solves are far slower on
            // ill-conditioned text problems, starving OPA of iterations.
            scale_gamma: true,
            ..Default::default()
        };
        let dg_fn;
        let opa_hooks = match &opts.opa {
            Some(cfg) => {
                let theta_c = theta.clone();
                dg_fn = move |zz: &[f64]| prob.dg_dtheta_col(&theta_c, zz, 0);
                Some(OpaHooks {
                    dg_dtheta: &dg_fn,
                    config: *cfg,
                })
            }
            None => None,
        };
        let res = lbfgs_minimize(&obj, &z, &min_opts, opa_hooks, None);
        z = res.z.clone();

        // ---- backward pass
        let fwd = ForwardArtifacts {
            z: &res.z,
            inv: Some(&res.qn),
            low_rank: None,
        };
        // Tie the backward tolerance to the forward one (HOAG's schedule).
        let strategy = match opts.strategy {
            Strategy::Full { tol: _, max_iters } => Strategy::Full {
                tol: tol_k,
                max_iters,
            },
            Strategy::ShineRefine { iters, tol: _ } => Strategy::ShineRefine {
                iters,
                tol: tol_k,
            },
            s => s,
        };
        let hg =
            hypergrad_session(prob, outer, &theta, &fwd, strategy, warm_w.as_deref(), &mut sess);
        warm_w = Some(hg.w.clone());

        // ---- outer step with adaptive step size
        let g_norm = crate::linalg::vecops::nrm2(&hg.grad_theta);
        for (t, g) in theta.iter_mut().zip(&hg.grad_theta) {
            // Trust-region-style step: θ is a log-regularization weight, so
            // a move of more than 1 nat per outer iteration is never useful
            // and a single overshoot would swing λ by orders of magnitude.
            let delta = (step * g).clamp(-1.0, 1.0);
            *t -= delta;
            *t = t.clamp(-30.0, 10.0);
        }
        let val = outer.value(&z);
        if opts.adaptive_step {
            if val > prev_val + 1e-12 {
                step *= 0.5;
            } else {
                step *= 1.05;
            }
        }
        prev_val = val;

        trace.push(OuterPoint {
            k,
            time: sw.elapsed(),
            theta: theta.clone(),
            val_loss: val,
            test_loss: outer.test_value(&z),
            inner_iters: res.iters,
            inner_evals: res.n_evals,
            backward_matvecs: hg.backward_matvecs,
            hypergrad_norm: g_norm,
            fallback_used: hg.fallback_used,
        });
    }
    HoagResult {
        theta,
        z,
        total_time: sw.elapsed(),
        trace,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::quadratic::{QuadraticBilevel, QuadraticOuter};
    use crate::util::rng::Rng;

    fn setup(seed: u64, n: usize) -> (QuadraticBilevel, QuadraticOuter) {
        let mut rng = Rng::new(seed);
        let p = QuadraticBilevel::random(n, &mut rng);
        let outer = QuadraticOuter {
            target: p.target.clone(),
        };
        (p, outer)
    }

    fn final_val(res: &HoagResult) -> f64 {
        res.trace.last().unwrap().val_loss
    }

    #[test]
    fn hoag_with_full_strategy_decreases_val_loss() {
        let (p, outer) = setup(1, 10);
        let opts = HoagOptions {
            outer_iters: 30,
            strategy: Strategy::Full {
                tol: 1e-8,
                max_iters: usize::MAX,
            },
            ..Default::default()
        };
        let res = hoag_run(&p, &outer, &[0.5], &opts);
        let first = res.trace.first().unwrap().val_loss;
        assert!(
            final_val(&res) < first,
            "val did not decrease: {first} -> {}",
            final_val(&res)
        );
    }

    #[test]
    fn hoag_with_shine_tracks_full() {
        let (p, outer) = setup(2, 10);
        let mk = |strategy| HoagOptions {
            outer_iters: 30,
            strategy,
            ..Default::default()
        };
        let full = hoag_run(
            &p,
            &outer,
            &[0.5],
            &mk(Strategy::Full {
                tol: 1e-8,
                max_iters: usize::MAX,
            }),
        );
        let shine = hoag_run(&p, &outer, &[0.5], &mk(Strategy::Shine));
        // Both should land in the same val-loss basin.
        let rel = (final_val(&shine) - final_val(&full)).abs() / final_val(&full).abs().max(1e-9);
        assert!(
            rel < 0.5,
            "shine {} vs full {}",
            final_val(&shine),
            final_val(&full)
        );
    }

    #[test]
    fn trace_is_monotone_in_time() {
        let (p, outer) = setup(3, 6);
        let res = hoag_run(
            &p,
            &outer,
            &[0.0],
            &HoagOptions {
                outer_iters: 10,
                ..Default::default()
            },
        );
        assert_eq!(res.trace.len(), 10);
        for w in res.trace.windows(2) {
            assert!(w[1].time >= w[0].time);
        }
    }

    #[test]
    fn opa_variant_runs_and_decreases() {
        let (p, outer) = setup(4, 8);
        let opts = HoagOptions {
            outer_iters: 20,
            opa: Some(OpaConfig { freq: 5, t0: 1.0 }),
            inner_memory: 60,
            strategy: Strategy::Shine,
            ..Default::default()
        };
        let res = hoag_run(&p, &outer, &[0.5], &opts);
        let first = res.trace.first().unwrap().val_loss;
        assert!(final_val(&res) <= first);
    }

    #[test]
    fn time_budget_respected() {
        let (p, outer) = setup(5, 6);
        let res = hoag_run(
            &p,
            &outer,
            &[0.0],
            &HoagOptions {
                outer_iters: 100_000,
                time_budget: 0.2,
                ..Default::default()
            },
        );
        assert!(res.total_time < 5.0);
        assert!(res.trace.len() < 100_000);
    }
}
