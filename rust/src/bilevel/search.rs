//! Grid and random search baselines (Fig. 1 / Fig. E.1 include both;
//! Bergstra & Bengio 2012). Each candidate θ gets a full inner solve with
//! the same L-BFGS solver the gradient-based methods use, so the comparison
//! is solver-fair; the trace records the best-so-far test loss over time,
//! matching how the paper plots search baselines.

use crate::problems::{InnerProblem, OuterLoss};
use crate::solvers::minimize::{lbfgs_minimize, MinimizeOptions};
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

#[derive(Clone, Debug)]
pub struct SearchPoint {
    pub time: f64,
    pub theta: f64,
    pub val_loss: f64,
    pub test_loss: f64,
    /// best-so-far (by validation) test loss — the reported curve
    pub best_test_loss: f64,
}

#[derive(Debug)]
pub struct SearchResult {
    pub best_theta: f64,
    pub best_val: f64,
    pub trace: Vec<SearchPoint>,
}

fn evaluate_candidates(
    prob: &dyn InnerProblem,
    outer: &dyn OuterLoss,
    thetas: &[f64],
    tol: f64,
    max_iters: usize,
    time_budget: f64,
) -> SearchResult {
    let sw = Stopwatch::start();
    let d = prob.dim();
    let mut best_val = f64::INFINITY;
    let mut best_theta = f64::NAN;
    let mut best_test = f64::INFINITY;
    let mut trace = Vec::new();
    let mut z = vec![0.0; d];
    for &t in thetas {
        if sw.elapsed() > time_budget {
            break;
        }
        let theta = [t];
        let obj = (d, |zz: &[f64]| {
            (
                prob.inner_value(&theta, zz)
                    .expect("search requires a minimization inner problem"),
                prob.g(&theta, zz),
            )
        });
        let res = lbfgs_minimize(
            &obj,
            &z,
            &MinimizeOptions {
                tol,
                max_iters,
                ..Default::default()
            },
            None,
            None,
        );
        z = res.z; // warm start the next candidate
        let val = outer.value(&z);
        let test = outer.test_value(&z);
        if val < best_val {
            best_val = val;
            best_theta = t;
            best_test = test;
        }
        trace.push(SearchPoint {
            time: sw.elapsed(),
            theta: t,
            val_loss: val,
            test_loss: test,
            best_test_loss: best_test,
        });
    }
    SearchResult {
        best_theta,
        best_val,
        trace,
    }
}

/// Grid search over log-regularization values in [lo, hi] (inclusive).
pub fn grid_search(
    prob: &dyn InnerProblem,
    outer: &dyn OuterLoss,
    lo: f64,
    hi: f64,
    n_points: usize,
    tol: f64,
    max_iters: usize,
    time_budget: f64,
) -> SearchResult {
    let thetas: Vec<f64> = (0..n_points)
        .map(|i| lo + (hi - lo) * i as f64 / (n_points.max(2) - 1) as f64)
        .collect();
    evaluate_candidates(prob, outer, &thetas, tol, max_iters, time_budget)
}

/// Random search: uniform samples of θ in [lo, hi].
#[allow(clippy::too_many_arguments)]
pub fn random_search(
    prob: &dyn InnerProblem,
    outer: &dyn OuterLoss,
    lo: f64,
    hi: f64,
    n_points: usize,
    tol: f64,
    max_iters: usize,
    time_budget: f64,
    rng: &mut Rng,
) -> SearchResult {
    let thetas: Vec<f64> = (0..n_points).map(|_| rng.uniform_in(lo, hi)).collect();
    evaluate_candidates(prob, outer, &thetas, tol, max_iters, time_budget)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::quadratic::{QuadraticBilevel, QuadraticOuter};
    use crate::util::rng::Rng;

    #[test]
    fn grid_finds_reasonable_theta() {
        let mut rng = Rng::new(6);
        let p = QuadraticBilevel::random(8, &mut rng);
        let outer = QuadraticOuter {
            target: p.target.clone(),
        };
        let res = grid_search(&p, &outer, -6.0, 3.0, 12, 1e-8, 2000, 60.0);
        assert_eq!(res.trace.len(), 12);
        assert!(res.best_theta.is_finite());
        // best-so-far is non-increasing
        for w in res.trace.windows(2) {
            assert!(w[1].best_test_loss <= w[0].best_test_loss + 1e-12);
        }
    }

    #[test]
    fn random_search_deterministic_under_seed() {
        let mut rng1 = Rng::new(9);
        let p = QuadraticBilevel::random(6, &mut rng1);
        let outer = QuadraticOuter {
            target: p.target.clone(),
        };
        let mut s1 = Rng::new(77);
        let mut s2 = Rng::new(77);
        let r1 = random_search(&p, &outer, -5.0, 2.0, 6, 1e-8, 1000, 60.0, &mut s1);
        let r2 = random_search(&p, &outer, -5.0, 2.0, 6, 1e-8, 1000, 60.0, &mut s2);
        assert_eq!(r1.best_theta, r2.best_theta);
    }
}
