//! Bi-level outer loops (the Fig. 1 / 2 / E.1 / E.2 drivers).
//!
//! * [`hoag`] — inexact hypergradient descent à la HOAG (Pedregosa 2016):
//!   warm-restarted inner solves with a geometrically decreasing tolerance,
//!   pluggable backward strategy (Original / SHINE / Jacobian-Free / refine
//!   / fallback), optional OPA on the inner solver.
//! * [`search`] — grid search and random search baselines (Bergstra &
//!   Bengio 2012), evaluated with the same inner solver for fairness.

pub mod hoag;
pub mod search;

pub use hoag::{hoag_run, HoagOptions, HoagResult, OuterPoint};
pub use search::{grid_search, random_search, SearchResult};
