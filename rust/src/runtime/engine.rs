//! PJRT execution engine.
//!
//! Owns the CPU PJRT client and a lazy cache of compiled executables, one
//! per artifact. Artifacts are HLO *text* (see aot.py for why), parsed with
//! `HloModuleProto::from_text_file` and compiled once; subsequent calls
//! reuse the compiled executable — compilation is O(100ms), execution is
//! the hot path.
//!
//! The PJRT path requires the external `xla` crate (heavy, pulls the PJRT C
//! API). It is gated behind the off-by-default `pjrt` cargo feature so the
//! crate builds hermetically; without it [`Engine::load`] returns an error
//! and every consumer (trainer, DEQ experiments, integration tests) skips
//! gracefully, exactly as they do when the AOT artifacts are missing. To
//! enable: add the `xla` dependency in Cargo.toml and build with
//! `--features pjrt`.

use crate::runtime::manifest::Manifest;
use anyhow::{anyhow, Result};
use std::cell::RefCell;
use std::collections::HashMap;

/// A shaped f32 tensor crossing the PJRT boundary.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor {
            shape,
            data: vec![0.0; n],
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Build from f64 slice (the qN stack is f64; PJRT artifacts are f32).
    pub fn from_f64(shape: Vec<usize>, data: &[f64]) -> Tensor {
        Tensor {
            shape,
            data: data.iter().map(|&x| x as f32).collect(),
        }
    }

    pub fn to_f64(&self) -> Vec<f64> {
        self.data.iter().map(|&x| x as f64).collect()
    }
}

/// Stub engine compiled when the `pjrt` feature is off: keeps the full API
/// surface so callers typecheck, but `load` always errors and downstream
/// code takes its artifact-missing skip path.
#[cfg(not(feature = "pjrt"))]
pub struct Engine {
    pub manifest: Manifest,
    /// cumulative number of artifact executions (perf accounting)
    pub calls: RefCell<HashMap<String, usize>>,
}

#[cfg(not(feature = "pjrt"))]
impl Engine {
    /// Always fails: the PJRT client is not compiled in.
    pub fn load(_dir: &str) -> Result<Engine> {
        Err(anyhow!(
            "PJRT runtime not available: crate built without the `pjrt` feature \
             (add the `xla` dependency and build with --features pjrt)"
        ))
    }

    /// Default artifact directory (env override: SHINE_ARTIFACTS).
    pub fn default_dir() -> String {
        std::env::var("SHINE_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
    }

    pub fn warmup_variant(&self, _variant: &str) -> Result<()> {
        Err(anyhow!("PJRT runtime not available (`pjrt` feature off)"))
    }

    pub fn call(&self, name: &str, _inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        Err(anyhow!(
            "PJRT runtime not available (`pjrt` feature off): cannot execute artifact '{name}'"
        ))
    }

    /// Total artifact calls so far (per name).
    pub fn call_counts(&self) -> HashMap<String, usize> {
        self.calls.borrow().clone()
    }
}

/// PJRT engine with executable cache.
#[cfg(feature = "pjrt")]
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: String,
    cache: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
    /// cumulative number of artifact executions (perf accounting)
    pub calls: RefCell<HashMap<String, usize>>,
}

#[cfg(feature = "pjrt")]
impl Engine {
    /// Load the manifest and connect the PJRT CPU client.
    pub fn load(dir: &str) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Engine {
            client,
            manifest,
            dir: dir.to_string(),
            cache: RefCell::new(HashMap::new()),
            calls: RefCell::new(HashMap::new()),
        })
    }

    /// Default artifact directory (env override: SHINE_ARTIFACTS).
    pub fn default_dir() -> String {
        std::env::var("SHINE_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
    }

    fn compile(&self, name: &str) -> Result<()> {
        let rec = self.manifest.artifact(name)?;
        let path = format!("{}/{}", self.dir, rec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing HLO {path}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        self.cache.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    /// Eagerly compile every artifact of a variant (so timing runs do not
    /// pay compilation inside the measured region).
    pub fn warmup_variant(&self, variant: &str) -> Result<()> {
        let names: Vec<String> = self
            .manifest
            .artifacts
            .keys()
            .filter(|k| k.starts_with(&format!("{variant}_")))
            .cloned()
            .collect();
        for n in names {
            if !self.cache.borrow().contains_key(&n) {
                self.compile(&n)?;
            }
        }
        Ok(())
    }

    /// Execute artifact `name` with the given inputs; returns one Tensor per
    /// output in the manifest's output order.
    pub fn call(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let rec = self.manifest.artifact(name)?.clone();
        // Shape check against the manifest ABI.
        if inputs.len() != rec.inputs.len() {
            return Err(anyhow!(
                "{name}: expected {} inputs, got {}",
                rec.inputs.len(),
                inputs.len()
            ));
        }
        for (i, (t, want)) in inputs.iter().zip(&rec.inputs).enumerate() {
            if &t.shape != want {
                return Err(anyhow!(
                    "{name}: input {i} shape {:?} != manifest {:?}",
                    t.shape,
                    want
                ));
            }
        }
        if !self.cache.borrow().contains_key(name) {
            self.compile(name)?;
        }
        *self
            .calls
            .borrow_mut()
            .entry(name.to_string())
            .or_insert(0) += 1;

        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let lit = xla::Literal::vec1(&t.data);
                if t.shape.len() == 1 {
                    Ok(lit)
                } else {
                    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                    lit.reshape(&dims).map_err(|e| anyhow!("reshape: {e:?}"))
                }
            })
            .collect::<Result<_>>()?;
        let cache = self.cache.borrow();
        let exe = cache.get(name).unwrap();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result {name}: {e:?}"))?;
        // aot.py lowers with return_tuple=True: decompose the tuple.
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow!("tuple decompose {name}: {e:?}"))?;
        if parts.len() != rec.outputs.len() {
            return Err(anyhow!(
                "{name}: {} outputs vs manifest {}",
                parts.len(),
                rec.outputs.len()
            ));
        }
        parts
            .into_iter()
            .zip(&rec.outputs)
            .map(|(lit, shape)| {
                let data = lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("output to_vec {name}: {e:?}"))?;
                if data.len() != shape.iter().product::<usize>() {
                    return Err(anyhow!(
                        "{name}: output len {} vs manifest shape {:?}",
                        data.len(),
                        shape
                    ));
                }
                Ok(Tensor::new(shape.clone(), data))
            })
            .collect()
    }

    /// Total artifact calls so far (per name).
    pub fn call_counts(&self) -> HashMap<String, usize> {
        self.calls.borrow().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_roundtrip_f64() {
        let t = Tensor::from_f64(vec![2, 2], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.shape, vec![2, 2]);
        assert_eq!(t.to_f64(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.len(), 4);
    }

    #[test]
    fn zeros_shape() {
        let t = Tensor::zeros(vec![3, 5]);
        assert_eq!(t.len(), 15);
        assert!(t.data.iter().all(|&x| x == 0.0));
    }
    // Engine execution is exercised by rust/tests/runtime_integration.rs
    // (requires built artifacts).
}
