//! Typed view of `artifacts/manifest.json` — the ABI between the JAX
//! compile path and the Rust run path. Every artifact call is shape-checked
//! against this manifest before it reaches PJRT (a wrong shape would
//! otherwise surface as an opaque XLA error deep in the C API).

use crate::util::json::{self, Json};
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;

#[derive(Clone, Debug)]
pub struct VariantCfg {
    pub name: String,
    pub batch: usize,
    pub h: usize,
    pub w: usize,
    pub c_in: usize,
    pub patch: usize,
    pub c: usize,
    pub n_classes: usize,
    pub unroll: usize,
    pub pixels: usize,
    pub patch_channels: usize,
    pub fixed_point_dim: usize,
    /// (name, shape) in the canonical parameter order.
    pub param_shapes: Vec<(String, Vec<usize>)>,
    /// names of the parameters f_theta depends on (w1..beta)
    pub f_param_names: Vec<String>,
}

impl VariantCfg {
    /// Flattened length of parameter `name`.
    pub fn param_len(&self, name: &str) -> usize {
        self.param_shapes
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, s)| s.iter().product())
            .unwrap_or_else(|| panic!("unknown param {name}"))
    }

    /// Index of parameter `name` in the canonical order.
    pub fn param_index(&self, name: &str) -> usize {
        self.param_shapes
            .iter()
            .position(|(n, _)| n == name)
            .unwrap_or_else(|| panic!("unknown param {name}"))
    }

    /// shape of the fixed point tensor (batch, pixels, c)
    pub fn z_shape(&self) -> Vec<usize> {
        vec![self.batch, self.pixels, self.c]
    }

    pub fn x_shape(&self) -> Vec<usize> {
        vec![self.batch, self.h * self.w * self.c_in]
    }

    pub fn y_shape(&self) -> Vec<usize> {
        vec![self.batch, self.n_classes]
    }
}

/// The element type every host buffer crossing the PJRT boundary must
/// have. The AOT artifacts are compiled for f32 tensors; reduced-precision
/// panel storage (`Bf16`/`F16` in [`crate::linalg::vecops`]) is a
/// *host-side* layout and must be widened before it reaches an artifact —
/// `LowRank::pack_f32` is the sanctioned conversion point.
pub const ARTIFACT_DTYPE: &str = "f32";

#[derive(Clone, Debug)]
pub struct ArtifactRec {
    pub file: String,
    /// Element type of every input/output tensor. Optional in the JSON
    /// (defaults to `"f32"`, the only dtype the run path ships); any other
    /// value is rejected at load time rather than silently reinterpreted.
    pub dtype: String,
    pub inputs: Vec<Vec<usize>>,
    pub outputs: Vec<Vec<usize>>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub variants: BTreeMap<String, VariantCfg>,
    pub artifacts: BTreeMap<String, ArtifactRec>,
}

fn shapes_from(j: &Json) -> Result<Vec<Vec<usize>>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("expected array of shapes"))?
        .iter()
        .map(|s| {
            s.as_arr()
                .ok_or_else(|| anyhow!("expected shape array"))
                .map(|dims| dims.iter().filter_map(|d| d.as_usize()).collect())
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: &str) -> Result<Manifest> {
        let path = format!("{dir}/manifest.json");
        let j = json::read_file(&path).with_context(|| {
            format!("loading {path}; run `make artifacts` to build the AOT artifacts")
        })?;
        let mut variants = BTreeMap::new();
        for (name, v) in j
            .get("variants")
            .and_then(|v| v.as_obj())
            .ok_or_else(|| anyhow!("manifest missing variants"))?
        {
            let get = |k: &str| -> Result<usize> {
                v.get(k)
                    .and_then(|x| x.as_usize())
                    .ok_or_else(|| anyhow!("variant {name} missing {k}"))
            };
            let param_names: Vec<String> = v
                .get("param_names")
                .and_then(|x| x.as_arr())
                .ok_or_else(|| anyhow!("missing param_names"))?
                .iter()
                .filter_map(|s| s.as_str().map(String::from))
                .collect();
            let shapes_obj = v
                .get("param_shapes")
                .and_then(|x| x.as_obj())
                .ok_or_else(|| anyhow!("missing param_shapes"))?;
            let param_shapes: Vec<(String, Vec<usize>)> = param_names
                .iter()
                .map(|n| {
                    let dims = shapes_obj
                        .get(n)
                        .and_then(|s| s.as_arr())
                        .map(|a| a.iter().filter_map(|d| d.as_usize()).collect())
                        .unwrap_or_default();
                    (n.clone(), dims)
                })
                .collect();
            let f_param_names = v
                .get("f_param_names")
                .and_then(|x| x.as_arr())
                .map(|a| a.iter().filter_map(|s| s.as_str().map(String::from)).collect())
                .unwrap_or_default();
            variants.insert(
                name.clone(),
                VariantCfg {
                    name: name.clone(),
                    batch: get("batch")?,
                    h: get("h")?,
                    w: get("w")?,
                    c_in: get("c_in")?,
                    patch: get("patch")?,
                    c: get("c")?,
                    n_classes: get("n_classes")?,
                    unroll: get("unroll")?,
                    pixels: get("pixels")?,
                    patch_channels: get("patch_channels")?,
                    fixed_point_dim: get("fixed_point_dim")?,
                    param_shapes,
                    f_param_names,
                },
            );
        }
        let mut artifacts = BTreeMap::new();
        for (name, a) in j
            .get("artifacts")
            .and_then(|v| v.as_obj())
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
        {
            let dtype = a
                .get("dtype")
                .and_then(|d| d.as_str())
                .unwrap_or(ARTIFACT_DTYPE)
                .to_string();
            // Reject rather than reinterpret: the Rust host buffers handed
            // to PJRT are f32 slices, so a manifest declaring any other
            // dtype would silently read garbage. Reduced-precision panels
            // must be widened first (`LowRank::pack_f32`).
            if dtype != ARTIFACT_DTYPE {
                return Err(anyhow!(
                    "artifact {name} declares dtype '{dtype}' but the run path only \
                     ships {ARTIFACT_DTYPE} host buffers; re-export the artifact at \
                     {ARTIFACT_DTYPE} (reduced-precision panel storage is host-side \
                     only — widen via LowRank::pack_f32 before the PJRT boundary)"
                ));
            }
            artifacts.insert(
                name.clone(),
                ArtifactRec {
                    file: a
                        .get("file")
                        .and_then(|f| f.as_str())
                        .ok_or_else(|| anyhow!("artifact {name} missing file"))?
                        .to_string(),
                    dtype,
                    inputs: shapes_from(a.get("inputs").ok_or_else(|| anyhow!("no inputs"))?)?,
                    outputs: shapes_from(a.get("outputs").ok_or_else(|| anyhow!("no outputs"))?)?,
                },
            );
        }
        Ok(Manifest {
            variants,
            artifacts,
        })
    }

    pub fn variant(&self, name: &str) -> Result<&VariantCfg> {
        self.variants
            .get(name)
            .ok_or_else(|| anyhow!("unknown variant '{name}' (have: {:?})", self.variants.keys()))
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactRec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A miniature manifest for parser tests (real manifests are covered by
    /// the integration tests that need built artifacts).
    const DOC: &str = r#"{
      "version": 1,
      "variants": {
        "tiny": {
          "batch": 4, "h": 8, "w": 8, "c_in": 3, "patch": 2, "c": 8,
          "n_classes": 4, "unroll": 4, "pixels": 16, "patch_channels": 12,
          "fixed_point_dim": 512,
          "param_names": ["wemb", "bemb"],
          "f_param_names": ["w1"],
          "param_shapes": {"wemb": [12, 8], "bemb": [8]}
        }
      },
      "artifacts": {
        "tiny_f_fwd": {
          "file": "tiny_f_fwd.hlo.txt",
          "inputs": [[8, 8], [8]],
          "outputs": [[4, 16, 8]],
          "sha256": "abc"
        }
      }
    }"#;

    #[test]
    fn parses_manifest() {
        let dir = std::env::temp_dir().join("shine_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), DOC).unwrap();
        let m = Manifest::load(dir.to_str().unwrap()).unwrap();
        let v = m.variant("tiny").unwrap();
        assert_eq!(v.fixed_point_dim, 512);
        assert_eq!(v.param_len("wemb"), 96);
        assert_eq!(v.param_index("bemb"), 1);
        assert_eq!(v.z_shape(), vec![4, 16, 8]);
        let a = m.artifact("tiny_f_fwd").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.outputs[0], vec![4, 16, 8]);
        assert_eq!(a.dtype, ARTIFACT_DTYPE, "absent dtype defaults to f32");
        assert!(m.variant("nope").is_err());
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn non_f32_artifact_dtype_is_rejected() {
        // A manifest declaring bf16 tensors must fail loudly at load time:
        // the host side hands PJRT f32 slices, so accepting it would
        // reinterpret bits. (Reduced-precision panels widen through
        // LowRank::pack_f32 instead.)
        let doc = DOC.replace(
            "\"file\": \"tiny_f_fwd.hlo.txt\",",
            "\"file\": \"tiny_f_fwd.hlo.txt\",\n          \"dtype\": \"bf16\",",
        );
        let dir = std::env::temp_dir().join("shine_manifest_dtype_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), doc).unwrap();
        let err = Manifest::load(dir.to_str().unwrap()).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("bf16"), "error names the offending dtype: {msg}");
        assert!(msg.contains("pack_f32"), "error points at the conversion: {msg}");

        // An explicit f32 declaration loads fine.
        let doc32 = DOC.replace(
            "\"file\": \"tiny_f_fwd.hlo.txt\",",
            "\"file\": \"tiny_f_fwd.hlo.txt\",\n          \"dtype\": \"f32\",",
        );
        std::fs::write(dir.join("manifest.json"), doc32).unwrap();
        let m = Manifest::load(dir.to_str().unwrap()).unwrap();
        assert_eq!(m.artifact("tiny_f_fwd").unwrap().dtype, "f32");
    }
}
