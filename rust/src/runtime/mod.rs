//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! and executes them from the Rust hot path. Python never runs here.
//!
//! * [`manifest`] — typed view of `artifacts/manifest.json` (shapes, param
//!   layout, variant configs — the ABI shared with the python side).
//! * [`engine`] — PJRT CPU client + per-artifact compiled-executable cache +
//!   `Literal` ⇄ `Vec<f32>` conversion.

pub mod engine;
pub mod manifest;

pub use engine::{Engine, Tensor};
pub use manifest::{ArtifactRec, Manifest, VariantCfg};
