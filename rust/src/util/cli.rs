//! Declarative command-line flag parser (offline replacement for `clap`).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, defaults, and auto-generated `--help` text. Intentionally tiny:
//! the `shine` CLI has a handful of subcommands with flat flag sets.

use std::collections::BTreeMap;

#[derive(Clone, Debug)]
struct Spec {
    name: String,
    help: String,
    default: Option<String>,
    is_bool: bool,
}

/// Flag-set definition + parse result.
#[derive(Clone, Debug, Default)]
pub struct Args {
    specs: Vec<Spec>,
    values: BTreeMap<String, String>,
    positional: Vec<String>,
    about: String,
}

impl Args {
    pub fn new(about: &str) -> Self {
        Args {
            about: about.to_string(),
            ..Default::default()
        }
    }

    /// Declare a string/number flag with a default value.
    pub fn flag(mut self, name: &str, default: &str, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_bool: false,
        });
        self
    }

    /// Declare a required flag (no default).
    pub fn required(mut self, name: &str, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_bool: false,
        });
        self
    }

    /// Declare a boolean switch (default false).
    pub fn switch(mut self, name: &str, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some("false".to_string()),
            is_bool: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{}\n\nFlags:\n", self.about);
        for spec in &self.specs {
            let d = spec
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_else(|| " [required]".to_string());
            s.push_str(&format!("  --{:<24} {}{}\n", spec.name, spec.help, d));
        }
        s
    }

    /// Parse a token stream (excluding argv[0] / the subcommand).
    pub fn parse(mut self, argv: &[String]) -> anyhow::Result<Args> {
        for spec in &self.specs {
            if let Some(d) = &spec.default {
                self.values.insert(spec.name.clone(), d.clone());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "--help" || tok == "-h" {
                anyhow::bail!("{}", self.usage());
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| anyhow::anyhow!("unknown flag --{name}\n{}", self.usage()))?
                    .clone();
                let val = if let Some(v) = inline_val {
                    v
                } else if spec.is_bool {
                    "true".to_string()
                } else {
                    i += 1;
                    argv.get(i)
                        .ok_or_else(|| anyhow::anyhow!("flag --{name} requires a value"))?
                        .clone()
                };
                self.values.insert(name, val);
            } else {
                self.positional.push(tok.clone());
            }
            i += 1;
        }
        for spec in &self.specs {
            if !self.values.contains_key(&spec.name) {
                anyhow::bail!("missing required flag --{}\n{}", spec.name, self.usage());
            }
        }
        Ok(self)
    }

    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("flag --{name} not declared"))
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("flag --{name} is not a number"))
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("flag --{name} is not an integer"))
    }

    pub fn get_u64(&self, name: &str) -> u64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("flag --{name} is not an integer"))
    }

    pub fn get_bool(&self, name: &str) -> bool {
        self.get(name) == "true"
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = Args::new("t")
            .flag("seed", "42", "seed")
            .flag("tol", "1e-6", "tolerance")
            .switch("verbose", "chatty")
            .parse(&argv(&["--seed", "7", "--verbose"]))
            .unwrap();
        assert_eq!(a.get_u64("seed"), 7);
        assert_eq!(a.get_f64("tol"), 1e-6);
        assert!(a.get_bool("verbose"));
    }

    #[test]
    fn equals_syntax_and_positional() {
        let a = Args::new("t")
            .flag("n", "1", "count")
            .parse(&argv(&["pos1", "--n=5", "pos2"]))
            .unwrap();
        assert_eq!(a.get_usize("n"), 5);
        assert_eq!(a.positional(), &["pos1".to_string(), "pos2".to_string()]);
    }

    #[test]
    fn unknown_flag_errors() {
        let r = Args::new("t").parse(&argv(&["--nope", "1"]));
        assert!(r.is_err());
    }

    #[test]
    fn missing_required_errors() {
        let r = Args::new("t").required("must", "x").parse(&argv(&[]));
        assert!(r.is_err());
        let ok = Args::new("t")
            .required("must", "x")
            .parse(&argv(&["--must", "v"]))
            .unwrap();
        assert_eq!(ok.get("must"), "v");
    }
}
