//! Small statistics helpers shared by the bench harness and experiments.

/// Arithmetic mean. Empty input returns NaN.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Quantile with linear interpolation, q in [0,1].
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (s.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (pos - lo as f64) * (s[hi] - s[lo])
    }
}

/// Median (q=0.5), the paper's reported statistic for pass timings.
pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

/// Cosine similarity between two vectors (used in Fig. 2-right / Fig. E.3).
pub fn cosine_similarity(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let dot: f64 = a.iter().zip(b).map(|(x, y)| x * y).sum();
    let na: f64 = a.iter().map(|x| x * x).sum::<f64>().sqrt();
    let nb: f64 = b.iter().map(|x| x * x).sum::<f64>().sqrt();
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot / (na * nb)
}

/// Euclidean norm.
pub fn norm2(a: &[f64]) -> f64 {
    a.iter().map(|x| x * x).sum::<f64>().sqrt()
}

/// f32 variants used on the DEQ (artifact) path.
pub fn norm2_f32(a: &[f32]) -> f32 {
    // Accumulate in f64: d ~ 1e5 elements would lose bits in f32.
    (a.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()).sqrt() as f32
}

pub fn cosine_similarity_f32(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let dot: f64 = a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum();
    let na: f64 = a.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
    let nb: f64 = b.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot / (na * nb)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std(&xs) - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn quantiles() {
        let xs: Vec<f64> = (0..101).map(|i| i as f64).collect();
        assert_eq!(quantile(&xs, 0.0), 0.0);
        assert_eq!(quantile(&xs, 1.0), 100.0);
        assert!((quantile(&xs, 0.25) - 25.0).abs() < 1e-9);
    }

    #[test]
    fn cosine() {
        assert!((cosine_similarity(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-12);
        assert!(cosine_similarity(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-12);
        assert!((cosine_similarity(&[1.0, 1.0], &[-1.0, -1.0]) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs() {
        assert!(mean(&[]).is_nan());
        assert!(median(&[]).is_nan());
        assert_eq!(std(&[1.0]), 0.0);
    }
}
