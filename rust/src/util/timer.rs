//! Wall-clock timing helpers: scoped timers and cumulative phase timers.
//!
//! The bi-level experiments (Fig. 1/2/E.1/E.2) report *wall-clock time to a
//! given test loss*, so every outer iteration stamps `Stopwatch::elapsed`.
//! The DEQ experiments (Table E.2) report per-phase medians, accumulated via
//! `PhaseTimers`.

use std::collections::BTreeMap;
use std::time::Instant;

/// Simple stopwatch anchored at construction.
#[derive(Clone, Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch {
            start: Instant::now(),
        }
    }

    /// Seconds since start.
    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Milliseconds since start.
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed() * 1e3
    }
}

/// Named cumulative timers: `timers.time("backward", || ...)`.
#[derive(Default, Debug)]
pub struct PhaseTimers {
    totals: BTreeMap<String, f64>,
    counts: BTreeMap<String, usize>,
    samples: BTreeMap<String, Vec<f64>>,
}

impl PhaseTimers {
    pub fn new() -> Self {
        Self::default()
    }

    /// Run `f`, attribute its wall time to `phase`, return its value.
    pub fn time<T>(&mut self, phase: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        let dt = t0.elapsed().as_secs_f64();
        *self.totals.entry(phase.to_string()).or_insert(0.0) += dt;
        *self.counts.entry(phase.to_string()).or_insert(0) += 1;
        self.samples.entry(phase.to_string()).or_default().push(dt);
        out
    }

    /// Total seconds attributed to a phase.
    pub fn total(&self, phase: &str) -> f64 {
        self.totals.get(phase).copied().unwrap_or(0.0)
    }

    pub fn count(&self, phase: &str) -> usize {
        self.counts.get(phase).copied().unwrap_or(0)
    }

    /// Median of individual samples (paper reports medians for pass times).
    pub fn median_ms(&self, phase: &str) -> f64 {
        match self.samples.get(phase) {
            Some(s) if !s.is_empty() => crate::util::stats::median(s) * 1e3,
            _ => f64::NAN,
        }
    }

    pub fn phases(&self) -> impl Iterator<Item = (&str, f64, usize)> {
        self.totals
            .iter()
            .map(move |(k, &v)| (k.as_str(), v, self.count(k)))
    }

    /// Raw samples for a phase in seconds.
    pub fn samples(&self, phase: &str) -> &[f64] {
        self.samples.get(phase).map(|v| v.as_slice()).unwrap_or(&[])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        let a = sw.elapsed();
        let b = sw.elapsed();
        assert!(b >= a && a >= 0.0);
    }

    #[test]
    fn phase_timers_accumulate() {
        let mut t = PhaseTimers::new();
        let x = t.time("p", || 41 + 1);
        assert_eq!(x, 42);
        t.time("p", || std::thread::sleep(std::time::Duration::from_millis(1)));
        assert_eq!(t.count("p"), 2);
        assert!(t.total("p") > 0.0);
        assert!(t.median_ms("p") >= 0.0);
        assert_eq!(t.count("missing"), 0);
        assert!(t.median_ms("missing").is_nan());
    }
}
