//! Offline substrates: the registry cache in this build environment only
//! contains the `xla` crate's dependency closure, so the usual ecosystem
//! crates (`rand`, `serde_json`, `clap`, `criterion`, `proptest`) are
//! re-implemented here at the scale this project needs. See DESIGN.md §4.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;
pub mod threads;
