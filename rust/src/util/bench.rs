//! Benchmark harness (offline replacement for `criterion`).
//!
//! All `benches/*.rs` targets are `harness = false` binaries built on this
//! module. It provides: warmup, fixed-count or time-budget measurement,
//! robust summary statistics (median + IQR, the statistic the paper reports
//! for pass timings), and a table printer that emits both a human-readable
//! table and a machine-readable JSON file under `results/bench/`.

use crate::util::json::Json;
use crate::util::stats;
use std::time::Instant;

/// One measured series.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    /// Seconds per iteration.
    pub samples: Vec<f64>,
}

impl Measurement {
    pub fn median_ms(&self) -> f64 {
        stats::median(&self.samples) * 1e3
    }
    pub fn p25_ms(&self) -> f64 {
        stats::quantile(&self.samples, 0.25) * 1e3
    }
    pub fn p75_ms(&self) -> f64 {
        stats::quantile(&self.samples, 0.75) * 1e3
    }
    pub fn mean_ms(&self) -> f64 {
        stats::mean(&self.samples) * 1e3
    }
}

/// Bench runner with warmup + sample count policy.
pub struct Bench {
    pub warmup: usize,
    pub samples: usize,
    /// Optional wall-clock cap in seconds; sampling stops early once hit.
    pub max_seconds: f64,
    measurements: Vec<Measurement>,
    title: String,
}

impl Bench {
    pub fn new(title: &str) -> Self {
        Bench {
            warmup: 3,
            samples: 30,
            max_seconds: 60.0,
            measurements: Vec::new(),
            title: title.to_string(),
        }
    }

    pub fn with_samples(mut self, warmup: usize, samples: usize) -> Self {
        self.warmup = warmup;
        self.samples = samples;
        self
    }

    pub fn with_budget(mut self, seconds: f64) -> Self {
        self.max_seconds = seconds;
        self
    }

    /// Measure `f` (each call = one iteration). `f` may return a value which
    /// is black-boxed to prevent the optimizer from deleting the work.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Measurement {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut samples = Vec::with_capacity(self.samples);
        let budget_start = Instant::now();
        for _ in 0..self.samples {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
            if budget_start.elapsed().as_secs_f64() > self.max_seconds {
                break;
            }
        }
        self.measurements.push(Measurement {
            name: name.to_string(),
            samples,
        });
        self.measurements.last().unwrap()
    }

    /// Record a pre-measured series (e.g. timings captured inside a trainer).
    pub fn record(&mut self, name: &str, samples_secs: Vec<f64>) {
        self.measurements.push(Measurement {
            name: name.to_string(),
            samples: samples_secs,
        });
    }

    /// Print the summary table and persist JSON to `results/bench/<slug>.json`.
    pub fn finish(&self) {
        println!("\n== {} ==", self.title);
        println!(
            "{:<44} {:>10} {:>10} {:>10} {:>6}",
            "benchmark", "median", "p25", "p75", "n"
        );
        for m in &self.measurements {
            println!(
                "{:<44} {:>8.3}ms {:>8.3}ms {:>8.3}ms {:>6}",
                m.name,
                m.median_ms(),
                m.p25_ms(),
                m.p75_ms(),
                m.samples.len()
            );
        }
        let slug: String = self
            .title
            .to_lowercase()
            .chars()
            .map(|c| if c.is_alphanumeric() { c } else { '_' })
            .collect();
        let mut j = Json::obj();
        j.set("title", self.title.as_str());
        let rows: Vec<Json> = self
            .measurements
            .iter()
            .map(|m| {
                let mut r = Json::obj();
                r.set("name", m.name.as_str())
                    .set("median_ms", m.median_ms())
                    .set("p25_ms", m.p25_ms())
                    .set("p75_ms", m.p75_ms())
                    .set("mean_ms", m.mean_ms())
                    .set("n", m.samples.len());
                r
            })
            .collect();
        j.set("rows", Json::Arr(rows));
        let path = format!("results/bench/{}.json", slug);
        if let Err(e) = crate::util::json::write_file(&path, &j) {
            eprintln!("warning: could not write {path}: {e}");
        } else {
            println!("(wrote {path})");
        }
    }

    pub fn measurements(&self) -> &[Measurement] {
        &self.measurements
    }
}

/// Optimizer barrier (stable-Rust equivalent of `std::hint::black_box` —
/// available since 1.66, re-exported here so benches have one import).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_summarizes() {
        let mut b = Bench::new("test bench").with_samples(1, 5);
        b.run("noop", || 1 + 1);
        let m = &b.measurements()[0];
        assert_eq!(m.samples.len(), 5);
        assert!(m.median_ms() >= 0.0);
        assert!(m.p75_ms() >= m.p25_ms());
    }

    #[test]
    fn records_external_series() {
        let mut b = Bench::new("rec");
        b.record("series", vec![0.001, 0.002, 0.003]);
        assert!((b.measurements()[0].median_ms() - 2.0).abs() < 1e-9);
    }
}
