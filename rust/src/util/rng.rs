//! Deterministic PRNG substrate (offline replacement for the `rand` crate).
//!
//! Implements xoshiro256++ seeded through SplitMix64, plus the sampling
//! helpers the experiments need (uniform, normal, permutation, categorical).
//! Every experiment in the repo threads an explicit seed through this type so
//! that results are bit-reproducible, mirroring the paper's reproducibility
//! statement ("we made sure to use seeds").

/// xoshiro256++ PRNG (Blackman & Vigna). 2^256-1 period, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

/// SplitMix64 step, used for seeding and stream derivation.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (e.g. per experiment repetition).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> [0,1) double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Unbiased via rejection.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (polar form avoided: branch-free cost
    /// is irrelevant here, correctness is what matters).
    pub fn normal(&mut self) -> f64 {
        // Draw u1 in (0,1] to avoid ln(0).
        let u1 = 1.0 - self.uniform();
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Vector of iid standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Vector of iid standard normals, f32.
    pub fn normal_vec_f32(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| (self.normal() as f32) * std).collect()
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample k distinct indices from 0..n (k <= n), order random.
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut p = self.permutation(n);
        p.truncate(k);
        p
    }

    /// Exponential with rate lambda.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -(1.0 - self.uniform()).ln() / lambda
    }

    /// Heavy-tailed interarrival gap with the given `mean` (seconds) and
    /// Pareto tail index `alpha` (> 1, else the mean diverges): a Lomax
    /// (Pareto type II, support [0, ∞)) sample by inverse CDF,
    /// `x = xm·((1−u)^(−1/α) − 1)` with scale `xm = mean·(α−1)`. Smaller
    /// `alpha` ⇒ fatter tail (occasional huge gaps between request bursts)
    /// at the same offered rate — the open-loop load shape where continuous
    /// batching beats discrete batch formation hardest.
    pub fn pareto_interarrival(&mut self, mean: f64, alpha: f64) -> f64 {
        assert!(mean > 0.0, "mean must be positive");
        assert!(alpha > 1.0, "alpha must exceed 1 for a finite mean");
        let xm = mean * (alpha - 1.0);
        let u = self.uniform();
        xm * ((1.0 - u).powf(-1.0 / alpha) - 1.0)
    }

    /// Zipf-like categorical over 0..n with exponent `a` (power-law), used by
    /// the synthetic text generator to mimic word-frequency statistics.
    pub fn zipf(&mut self, n: usize, a: f64) -> usize {
        // Inverse-CDF on precomputation-free approximation: rejection over
        // continuous power law then clamp. Good enough for data synthesis.
        loop {
            let u = self.uniform().max(1e-12);
            let x = ((n as f64).powf(1.0 - a) * u + (1.0 - u)).powf(1.0 / (1.0 - a));
            let k = x.floor() as usize;
            if k >= 1 && k <= n {
                return k - 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_clones() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_and_var() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.uniform()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 5e-3, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 5e-3, "var={var}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 1e-2, "mean={mean}");
        assert!((var - 1.0).abs() < 2e-2, "var={var}");
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut r = Rng::new(9);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(13);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn zipf_in_range_and_skewed() {
        let mut r = Rng::new(21);
        let mut lo = 0usize;
        let n = 20_000;
        for _ in 0..n {
            let k = r.zipf(1000, 1.2);
            assert!(k < 1000);
            if k < 10 {
                lo += 1;
            }
        }
        // Power law: small indices must dominate.
        assert!(lo > n / 4, "lo={lo}");
    }

    #[test]
    fn pareto_interarrival_moments_and_tail() {
        let mut r = Rng::new(77);
        let (mean, alpha) = (1.0, 2.5);
        let n = 200_000;
        let mut sum = 0.0f64;
        let mut big = 0usize; // gaps beyond 4× the mean
        for _ in 0..n {
            let x = r.pareto_interarrival(mean, alpha);
            assert!(x >= 0.0);
            sum += x;
            if x > 4.0 * mean {
                big += 1;
            }
        }
        let m = sum / n as f64;
        assert!((m - mean).abs() < 0.05, "sample mean {m}");
        // Lomax tail: P(X > 4·mean) = (1 + 4/(α−1))^(−α) ≈ 3.9% at
        // α = 2.5, heavier than the exponential's e⁻⁴ ≈ 1.8% at the same
        // mean — the burst-then-gap shape the open-loop driver relies on.
        let frac = big as f64 / n as f64;
        assert!(frac > 0.025 && frac < 0.055, "tail fraction {frac}");
        assert!(frac > (-4.0f64).exp(), "must out-tail the exponential");
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Rng::new(2);
        let ks = r.choose_k(50, 20);
        assert_eq!(ks.len(), 20);
        let mut s = ks.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
    }
}
