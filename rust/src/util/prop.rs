//! Property-testing harness (offline replacement for `proptest`).
//!
//! `check(name, cases, |rng| ...)` runs a property over `cases` independently
//! seeded inputs; on failure it panics with the failing case index and seed
//! so the exact case can be replayed with `replay(seed, ...)`. A lightweight
//! numeric shrinker is provided for scalar-parameterised properties.
//!
//! Used across the repo for the coordinator invariants DESIGN.md §6 lists
//! (secant conditions, SHINE==exact on quadratics, fallback guard, ...).

use crate::util::rng::Rng;

/// Run `prop` for `cases` independent seeded RNGs. `prop` returns
/// `Err(description)` to signal failure.
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let base = 0x5111_4E5E_EDu64; // stable base seed: reproducible CI
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at case {case}/{cases} (seed {seed:#x}): {msg}\n\
                 replay with: shine::util::prop::replay({seed:#x}, ...)"
            );
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay<F>(seed: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("replayed property failed (seed {seed:#x}): {msg}");
    }
}

/// Assert helper: closeness with context, for use inside properties.
pub fn ensure_close(a: f64, b: f64, tol: f64, what: &str) -> Result<(), String> {
    let denom = 1.0f64.max(a.abs()).max(b.abs());
    if !((a - b).abs() / denom <= tol) {
        return Err(format!("{what}: {a} vs {b} (rel tol {tol})"));
    }
    Ok(())
}

/// Assert helper: vector closeness in relative l2 norm.
pub fn ensure_close_vec(a: &[f64], b: &[f64], tol: f64, what: &str) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("{what}: length {} vs {}", a.len(), b.len()));
    }
    let diff: f64 = a
        .iter()
        .zip(b)
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f64>()
        .sqrt();
    let scale = 1.0f64
        .max(a.iter().map(|x| x * x).sum::<f64>().sqrt())
        .max(b.iter().map(|x| x * x).sum::<f64>().sqrt());
    if !(diff / scale <= tol) {
        return Err(format!(
            "{what}: ||a-b||={diff:.3e} scale={scale:.3e} rel tol {tol}"
        ));
    }
    Ok(())
}

/// Assert helper: plain boolean with message.
pub fn ensure(cond: bool, what: &str) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(what.to_string())
    }
}

/// Shrink a failing scalar parameter toward `lo` by bisection; returns the
/// smallest value (within `steps` bisections) that still fails `fails`.
pub fn shrink_scalar(mut hi: f64, lo: f64, steps: usize, mut fails: impl FnMut(f64) -> bool) -> f64 {
    debug_assert!(fails(hi));
    let mut good_lo = lo;
    for _ in 0..steps {
        let mid = 0.5 * (good_lo + hi);
        if fails(mid) {
            hi = mid;
        } else {
            good_lo = mid;
        }
    }
    hi
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum-commutes", 50, |rng| {
            let a = rng.uniform();
            let b = rng.uniform();
            ensure_close(a + b, b + a, 1e-15, "commutativity")
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports_seed() {
        check("always-fails", 3, |_| Err("nope".to_string()));
    }

    #[test]
    fn ensure_close_vec_catches_mismatch() {
        assert!(ensure_close_vec(&[1.0, 2.0], &[1.0, 2.0], 1e-12, "eq").is_ok());
        assert!(ensure_close_vec(&[1.0], &[2.0], 1e-6, "neq").is_err());
        assert!(ensure_close_vec(&[1.0], &[1.0, 2.0], 1e-6, "len").is_err());
    }

    #[test]
    fn shrinker_finds_threshold() {
        // Property "x >= 0.5 fails": shrinker should approach 0.5 from above.
        let s = shrink_scalar(1.0, 0.0, 40, |x| x >= 0.5);
        assert!((s - 0.5).abs() < 1e-9, "s={s}");
    }
}
