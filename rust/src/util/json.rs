//! Minimal JSON substrate (offline replacement for `serde_json`).
//!
//! Used for three things: (1) writing experiment results under `results/`,
//! (2) parsing the artifact manifest emitted by `python/compile/aot.py`,
//! (3) experiment config files. Supports the full JSON grammar minus
//! exotic number formats; numbers are f64 (adequate: the manifest only
//! carries small integers and strings).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics on non-object: programmer error).
    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Path accessor: `j.at(&["variants", "cifar", "dim"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = cur.get(p)?;
        }
        Some(cur)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, level: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    item.write(out, indent, level + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, level);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, val)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    val.write(out, indent, level + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, level);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, level: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * level {
            out.push(' ');
        }
    }
}

/// Append one JSON number. Integral values below 1e15 print as integers
/// (exact in f64, so they still round-trip bit-for-bit through `parse`);
/// everything else uses Rust's shortest-round-trip float `Display`.
/// Negative zero is excluded from the integer branch — `-0.0 as i64` is
/// `0`, which would drop the sign bit; float `Display` prints `-0`,
/// which parses back to `-0.0` exactly. Non-finite values encode as
/// `null` (JSON has no inf/nan — documented loss). Shared with the HTTP
/// response builder (`crate::http::json`).
pub(crate) fn write_num(out: &mut String, x: f64) {
    if x.is_finite() {
        if x == x.trunc() && x.abs() < 1e15 && !(x == 0.0 && x.is_sign_negative()) {
            let _ = write!(out, "{}", x as i64);
        } else {
            let _ = write!(out, "{}", x);
        }
    } else {
        out.push_str("null");
    }
}

/// Append `s` as a quoted, escaped JSON string. Shared with the HTTP
/// response builder (`crate::http::json`).
pub(crate) fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<f32> for Json {
    fn from(x: f32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i32> for Json {
    fn from(x: i32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(|x| x.into()).collect())
    }
}
impl From<&[f64]> for Json {
    fn from(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }
}

/// Parse error with byte offset for diagnosability.
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

/// Parse a JSON document (full input must be consumed).
pub fn parse(s: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        b: s.as_bytes(),
        pos: 0,
    };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            // Surrogate pairs: accept and combine.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let hex2 =
                                        std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                            .map_err(|_| self.err("bad surrogate"))?;
                                    let lo = u32::from_str_radix(hex2, 16)
                                        .map_err(|_| self.err("bad surrogate"))?;
                                    self.pos += 4;
                                    let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| self.err("invalid codepoint"))?);
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                c => {
                    // Re-borrow multibyte UTF-8 sequences wholesale.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let chunk = std::str::from_utf8(&self.b[start..start + len])
                            .map_err(|_| self.err("invalid utf8"))?;
                        s.push_str(chunk);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    if first >= 0xF0 {
        4
    } else if first >= 0xE0 {
        3
    } else {
        2
    }
}

/// Write a JSON value to a file, creating parent directories.
pub fn write_file(path: &str, v: &Json) -> std::io::Result<()> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, v.to_pretty())
}

/// Read and parse a JSON file.
pub fn read_file(path: &str) -> anyhow::Result<Json> {
    let s = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
    Ok(parse(&s).map_err(|e| anyhow::anyhow!("parsing {path}: {e}"))?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let mut j = Json::obj();
        j.set("name", "shine")
            .set("iters", 42usize)
            .set("tol", 1e-6)
            .set("ok", true)
            .set("xs", vec![1.0, 2.5, -3.0]);
        let s = j.to_string();
        let back = parse(&s).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn roundtrip_pretty() {
        let mut j = Json::obj();
        j.set("a", Json::obj().set("b", vec![1.0, 2.0]).clone());
        let back = parse(&j.to_pretty()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parses_manifest_like_doc() {
        let doc = r#"{
          "variants": {
            "cifar": {"batch": 32, "hw": 64, "c": 32, "artifacts": ["f_fwd", "inject"]},
            "imagenet": {"batch": 32, "hw": 144, "c": 40}
          },
          "version": 1
        }"#;
        let j = parse(doc).unwrap();
        assert_eq!(
            j.at(&["variants", "cifar", "batch"]).unwrap().as_usize(),
            Some(32)
        );
        assert_eq!(
            j.at(&["variants", "cifar", "artifacts"])
                .unwrap()
                .as_arr()
                .unwrap()
                .len(),
            2
        );
    }

    #[test]
    fn escapes() {
        let j = Json::Str("a\"b\\c\nd\te\u{1}".to_string());
        let s = j.to_string();
        assert_eq!(parse(&s).unwrap(), j);
    }

    #[test]
    fn unicode_escape() {
        let j = parse(r#""é😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "é😀");
    }

    #[test]
    fn utf8_passthrough() {
        let j = parse("\"héllo wörld 😀\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo wörld 😀");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(parse("0").unwrap().as_f64(), Some(0.0));
        let s = Json::Num(1e-9).to_string();
        assert!((parse(&s).unwrap().as_f64().unwrap() - 1e-9).abs() < 1e-18);
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn negative_zero_keeps_its_sign() {
        // -0.0 must not take the integer fast path (`-0.0 as i64` is 0):
        // the sign bit is part of the wire bit-parity contract.
        let s = Json::Num(-0.0).to_string();
        assert_eq!(s, "-0");
        let back = parse(&s).unwrap().as_f64().unwrap();
        assert_eq!(back.to_bits(), (-0.0f64).to_bits());
        // Positive zero still prints as a bare integer.
        assert_eq!(Json::Num(0.0).to_string(), "0");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::obj());
        assert_eq!(Json::Arr(vec![]).to_pretty(), "[]");
    }
}
