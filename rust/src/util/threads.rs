//! Tiny scoped parallel-map (offline replacement for `rayon` where the
//! experiments fan out over seeds). Uses `std::thread::scope`; work items
//! are distributed round-robin to at most `max_threads` workers.

/// Map `f` over `items` in parallel, preserving order of results.
pub fn par_map<T, R, F>(items: Vec<T>, max_threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = max_threads.max(1).min(n);
    if workers == 1 {
        return items.into_iter().map(f).collect();
    }
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let work: Vec<(usize, T)> = items.into_iter().enumerate().collect();
    // Partition round-robin into `workers` chunks.
    let mut chunks: Vec<Vec<(usize, T)>> = (0..workers).map(|_| Vec::new()).collect();
    for (i, item) in work {
        chunks[i % workers].push((i, item));
    }
    let results: Vec<Vec<(usize, R)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = chunks
            .into_iter()
            .map(|chunk| {
                let f = &f;
                scope.spawn(move || {
                    chunk
                        .into_iter()
                        .map(|(i, item)| (i, f(item)))
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for chunk in results {
        for (i, r) in chunk {
            slots[i] = Some(r);
        }
    }
    slots.into_iter().map(|s| s.unwrap()).collect()
}

/// Run `f` over near-equal contiguous chunks of `data` on scoped threads,
/// one chunk per worker; `f(offset, chunk)` receives the chunk's start index
/// in `data`. Used by the low-rank panel kernels to split a big apply across
/// rows/columns above a size threshold — below it, callers should stay on the
/// single-threaded path (spawning threads allocates and would defeat the
/// allocation-free solver loops).
pub fn par_chunks_mut<T, F>(data: &mut [T], workers: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = data.len();
    if n == 0 {
        return;
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        f(0, data);
        return;
    }
    let chunk = n.div_ceil(workers);
    std::thread::scope(|scope| {
        let f = &f;
        let handles: Vec<_> = data
            .chunks_mut(chunk)
            .enumerate()
            .map(|(i, c)| scope.spawn(move || f(i * chunk, c)))
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
}

/// Row-aligned variant of [`par_chunks_mut`]: `data` is a flat row-major
/// `rows × row_len` buffer and each worker receives a whole number of rows;
/// `f(first_row, chunk)` gets the index of its first row. Used by the DEQ
/// residual block where every output row is independent.
pub fn par_row_chunks_mut<T, F>(data: &mut [T], row_len: usize, workers: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if data.is_empty() || row_len == 0 {
        return;
    }
    debug_assert_eq!(data.len() % row_len, 0);
    let rows = data.len() / row_len;
    let workers = workers.max(1).min(rows);
    if workers == 1 {
        f(0, data);
        return;
    }
    let rows_per = rows.div_ceil(workers);
    std::thread::scope(|scope| {
        let f = &f;
        let handles: Vec<_> = data
            .chunks_mut(rows_per * row_len)
            .enumerate()
            .map(|(i, c)| scope.spawn(move || f(i * rows_per, c)))
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
}

/// Number of available CPUs (fallback 4).
pub fn ncpus() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// How many scheduler shards are concurrently driving kernels (set by
/// [`crate::serve::shard::ShardedRouter`]); divides the per-kernel worker
/// budget so N shards × per-kernel fan-out cannot oversubscribe the cores.
static ACTIVE_SHARDS: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(1);

/// Declare `n` active scheduler shards (clamped to ≥ 1) and return the
/// previous value so the caller can restore it on shutdown. Process-global:
/// concurrent routers see each other's setting, which only redistributes
/// the worker budget — every kernel is bit-identical at any worker count
/// (f64 per-row accumulation), so this is a performance knob, never a
/// correctness one.
pub fn set_active_shards(n: usize) -> usize {
    ACTIVE_SHARDS.swap(n.max(1), std::sync::atomic::Ordering::Relaxed)
}

/// The current active-shard count (1 unless a sharded router is running).
pub fn active_shards() -> usize {
    ACTIVE_SHARDS.load(std::sync::atomic::Ordering::Relaxed)
}

/// Worker-count heuristic shared by the residual/panel block evaluators:
/// 1 below `min_elems` total elements (spawning scoped threads costs more
/// than the sweep and would break the allocation-free hot loops), otherwise
/// up to `cap` workers bounded by the machine width **divided by the
/// active shard count** (each shard gets an equal slice of the cores, min
/// 1 — with one shard this degenerates to the historic behaviour). This is
/// the lever that makes *batched* serving faster than per-request dispatch:
/// a single request's block often sits below `min_elems`, while the same
/// residual over a B-wide state block crosses it and fans out.
pub fn workers_for(elems: usize, min_elems: usize, cap: usize) -> usize {
    if elems < min_elems {
        1
    } else {
        shard_capped(ncpus(), active_shards(), cap)
    }
}

/// The shard-aware budget split: `cpus / shards` (floor), clamped to
/// `[1, cap]`. Factored out of [`workers_for`] so the sharing math is
/// testable without touching the process-global shard count.
fn shard_capped(cpus: usize, shards: usize, cap: usize) -> usize {
    (cpus / shards.max(1)).max(1).min(cap.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = par_map((0..100).collect(), 8, |x: i32| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single() {
        let out: Vec<i32> = par_map(Vec::<i32>::new(), 4, |x| x);
        assert!(out.is_empty());
        assert_eq!(par_map(vec![7], 4, |x: i32| x + 1), vec![8]);
    }

    #[test]
    fn single_thread_path() {
        assert_eq!(par_map(vec![1, 2, 3], 1, |x: i32| x * x), vec![1, 4, 9]);
    }

    #[test]
    fn par_row_chunks_mut_is_row_aligned() {
        // 10 rows × 3 cols; every worker must see whole rows.
        let mut data = vec![0usize; 30];
        par_row_chunks_mut(&mut data, 3, 4, |row0, chunk| {
            assert_eq!(chunk.len() % 3, 0);
            for (k, row) in chunk.chunks_exact_mut(3).enumerate() {
                for x in row.iter_mut() {
                    *x = row0 + k;
                }
            }
        });
        for (i, x) in data.iter().enumerate() {
            assert_eq!(*x, i / 3);
        }
    }

    #[test]
    fn par_chunks_mut_covers_all_offsets() {
        let mut data = vec![0usize; 103];
        par_chunks_mut(&mut data, 7, |off, chunk| {
            for (i, x) in chunk.iter_mut().enumerate() {
                *x = off + i;
            }
        });
        for (i, x) in data.iter().enumerate() {
            assert_eq!(*x, i);
        }
        // Degenerate worker counts.
        let mut one = vec![0i32; 5];
        par_chunks_mut(&mut one, 1, |off, c| c[0] = off as i32 + 1);
        assert_eq!(one[0], 1);
        let mut empty: Vec<i32> = Vec::new();
        par_chunks_mut(&mut empty, 4, |_, _| panic!("must not run"));
    }

    #[test]
    fn workers_for_thresholds() {
        assert_eq!(workers_for(100, 1000, 8), 1);
        let w = workers_for(1000, 1000, 8);
        assert!((1..=8).contains(&w));
        // cap bounds the fan-out even on wide machines
        assert_eq!(workers_for(1 << 20, 1, 1), 1);
    }

    #[test]
    fn workers_for_divides_by_active_shards() {
        // The sharing math, exercised through the pure helper so the test
        // cannot race other tests that run sharded routers (the global
        // shard count is process-wide).
        assert_eq!(shard_capped(16, 1, 1024), 16, "one shard keeps the full budget");
        assert_eq!(shard_capped(16, 2, 1024), 8);
        assert_eq!(shard_capped(16, 4, 1024), 4);
        assert_eq!(shard_capped(16, 4, 2), 2, "explicit cap still binds");
        assert_eq!(shard_capped(8, 3, 1024), 2, "floor division");
        assert_eq!(shard_capped(16, 32, 1024), 1, "more shards than cores → 1 each");
        assert_eq!(shard_capped(4, 0, 8), 4, "zero shards clamped to 1");
        assert_eq!(shard_capped(4, 1, 0), 1, "zero cap clamped to 1");
        // No oversubscription: shards × per-shard workers ≤ cores whenever
        // the shard count itself fits the machine.
        for shards in 1..=32usize {
            for cpus in 1..=64usize {
                let w = shard_capped(cpus, shards, 1024);
                assert!(w >= 1);
                if shards <= cpus {
                    assert!(w * shards <= cpus, "{w}×{shards} oversubscribes {cpus}");
                }
            }
        }
        // The global knob returns the previous value (restore contract).
        let prev = set_active_shards(3);
        set_active_shards(prev);
        // Below the element threshold the shard count is irrelevant.
        assert_eq!(workers_for(4, 1 << 20, 1024), 1);
    }
}
