//! Tiny scoped parallel-map (offline replacement for `rayon` where the
//! experiments fan out over seeds). Uses `std::thread::scope`; work items
//! are distributed round-robin to at most `max_threads` workers.

/// Map `f` over `items` in parallel, preserving order of results.
pub fn par_map<T, R, F>(items: Vec<T>, max_threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = max_threads.max(1).min(n);
    if workers == 1 {
        return items.into_iter().map(f).collect();
    }
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let work: Vec<(usize, T)> = items.into_iter().enumerate().collect();
    // Partition round-robin into `workers` chunks.
    let mut chunks: Vec<Vec<(usize, T)>> = (0..workers).map(|_| Vec::new()).collect();
    for (i, item) in work {
        chunks[i % workers].push((i, item));
    }
    let results: Vec<Vec<(usize, R)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = chunks
            .into_iter()
            .map(|chunk| {
                let f = &f;
                scope.spawn(move || {
                    chunk
                        .into_iter()
                        .map(|(i, item)| (i, f(item)))
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for chunk in results {
        for (i, r) in chunk {
            slots[i] = Some(r);
        }
    }
    slots.into_iter().map(|s| s.unwrap()).collect()
}

/// Number of available CPUs (fallback 4).
pub fn ncpus() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = par_map((0..100).collect(), 8, |x: i32| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single() {
        let out: Vec<i32> = par_map(Vec::<i32>::new(), 4, |x| x);
        assert!(out.is_empty());
        assert_eq!(par_map(vec![7], 4, |x: i32| x + 1), vec![8]);
    }

    #[test]
    fn single_thread_path() {
        assert_eq!(par_map(vec![1, 2, 3], 1, |x: i32| x * x), vec![1, 4, 9]);
    }
}
