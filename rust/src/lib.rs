//! # SHINE — SHaring the INverse Estimate (ICLR 2022) reproduction
//!
//! A three-layer Rust + JAX + Pallas implementation of
//! *SHINE: SHaring the INverse Estimate from the forward pass for bi-level
//! optimization and implicit models* (Ramzi et al., ICLR 2022).
//!
//! Layers:
//! * **L3 (this crate)** — the coordinator: quasi-Newton solvers, the SHINE
//!   / Jacobian-Free / refine / fallback hypergradient strategies, the
//!   bi-level (HOAG-style) outer loop, the DEQ trainer, dataset generators,
//!   the experiment registry and the CLI.
//! * **L2 (python/compile/model.py)** — the DEQ compute graph in JAX,
//!   AOT-lowered once to HLO text artifacts.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the DEQ residual
//!   block and the low-rank (Sherman–Morrison) inverse application.
//!
//! The `runtime` module loads the artifacts through the PJRT C API (`xla`
//! crate, behind the off-by-default `pjrt` feature — without it a stub
//! engine errors on load and all artifact-dependent paths skip gracefully);
//! Python never runs on the experiment hot path.
//!
//! ## Hot-path architecture (session API over Elem + FactorPanel + Workspace)
//!
//! The crate's solve surface is the **session API**
//! ([`solvers::session`]): a [`solvers::session::SolverSpec`] (Picard |
//! Anderson | Broyden, with the authoritative tol/budget) builds a
//! [`solvers::session::FixedPointSolver`] trait object; its
//! [`solvers::session::SolveOutcome`] carries the captured
//! inverse-estimate handle ([`solvers::session::EstimateHandle`]); and the
//! companion [`solvers::session::Backward`] trait (Shine | JacobianFree |
//! Fallback | Refine | Full) consumes that handle — SHINE's "share the
//! inverse estimate from the forward pass" as a type-level contract. The
//! DEQ trainer, the HOAG outer loop (via `hypergrad_session`), the power
//! probes, the coordinator experiments, the serving tier and the CLI
//! (`--solver` / `--backward` specs) all go through it; the legacy free
//! functions in [`solvers::fixed_point`] are deprecated shims that
//! delegate (bit-identical, `rust/tests/session_parity.rs`).
//!
//! Underneath, the hottest path — applying and updating the
//! identity-plus-low-rank inverse estimates `H = I + Σ uᵢvᵢᵀ` — is built
//! on three primitives:
//!
//! * [`linalg::vecops::Elem`] — the storage scalar (`f64`, `f32`, or the
//!   hand-rolled 16-bit [`linalg::vecops::Bf16`] / [`linalg::vecops::F16`])
//!   the whole qN/solver stack is generic over, with the *store narrow,
//!   accumulate wide* contract: panels and iterates in `E`, every
//!   reduction in f64. The DEQ path runs `E = f32` end-to-end (half the
//!   panel traffic, no boundary casts against the f32 artifacts); the
//!   bi-level/HOAG path keeps the `f64` default; the serving tier can
//!   additionally demote cached estimate *panels* to bf16/f16 or the
//!   mixed U-bf16/V-f32 layout via the independent storage parameters on
//!   [`qn::LowRank`] (`LowRank<EU, EV>`) while state stays f32.
//!   `rust/tests/precision_parity.rs` proves the instantiations agree to
//!   the documented tolerances and pins the 16-bit conversions bit-level
//!   (exhaustive round-trips + round-to-nearest-even).
//! * [`qn::FactorPanel`] — contiguous row-major factor storage behind a
//!   ring buffer: `H x` is two streaming panel sweeps
//!   (`linalg::vecops::panel_gemv` → `panel_gemv_t`, thread-parallel above
//!   a size threshold via `util::threads::par_chunks_mut`), eviction is an
//!   O(1) ring rotation, and multi-RHS application
//!   (`qn::InvOp::apply_multi`) serves a whole batch of backward cotangents
//!   in one sweep — itself sharded across threads for large batches.
//! * [`qn::Workspace`] — a LIFO scratch arena owned by each
//!   [`solvers::session::Session`] and threaded through the solver stack
//!   (the session solvers, the linear backward solvers, the OPA updates,
//!   the `Backward` strategies, and the DEQ trainer), with a storage pool
//!   in `E` and an f64 accumulator pool for coefficients and the Anderson
//!   Gram system. Residuals use the write-into convention `g(z, out)`, so
//!   solver iteration loops perform zero heap allocations after warm-up —
//!   enforced in both precisions by a counting-allocator test
//!   (`rust/tests/qn_alloc.rs`) and measured against the legacy
//!   `Vec<Vec<f64>>` layout and the f64 panels by `benches/micro_qn.rs`
//!   (results in `BENCH_qn.json`).
//!
//! On top of these, [`serve`] packages the stack as a batched,
//! **multi-model** serving tier: B concurrent DEQ requests become one
//! contiguous d × B state block driven through a spec-built solver (one
//! residual evaluation per iteration for the whole block, converged
//! columns retired by swap-to-back compaction), every SHINE backward
//! cotangent of a batch is answered by a single `apply_t_multi` panel
//! sweep against the per-model cached calibration estimate, and a
//! [`serve::Router`] + [`serve::KeyedScheduler`] batch traffic per
//! [`serve::ModelKey`] (model id + parameter version) with trip-rate-driven
//! re-calibration — zero heap allocations per batch once an engine is warm
//! (`rust/tests/qn_alloc.rs`), routing invariants pinned by
//! `rust/tests/serve_routing.rs`, throughput tracked by
//! `benches/serve_throughput.rs` (`BENCH_serve.json`). The serving loop
//! itself is **continuous batching**
//! ([`serve::ServeEngine::process_streaming`]): requests are admitted into
//! columns freed by retirement mid-solve, with per-column iteration
//! budgets, straggler evict-and-retry and per-key adaptive width. The
//! engine's panel storage is selectable per instantiation
//! (`ServeEngine<E, EU, EV>`, CLI `--panel-precision`): calibration runs
//! at full state precision and the cached estimate is demoted once, with
//! the §3 fallback guard + [`serve::RecalibPolicy`] policing demotion
//! error — see `docs/ARCHITECTURE.md`,
//! `docs/adr/001-continuous-batching.md` and
//! `docs/adr/003-reduced-precision-panels.md`. The [`http`] module puts a
//! dependency-free network edge on that tier — a std-`TcpListener`
//! HTTP/1.1 server with lazy JSON scanning, end-to-end admission
//! control, and `/healthz` + `/metrics` over the sharded router
//! (`shine serve-http`, `docs/adr/005-http-front-end.md`).
//!
//! See DESIGN.md for the per-experiment index and EXPERIMENTS.md for
//! paper-vs-measured results.

pub mod bilevel;
pub mod coordinator;
pub mod data;
pub mod deq;
pub mod http;
pub mod hypergrad;
pub mod linalg;
pub mod power;
pub mod runtime;
pub mod problems;
pub mod qn;
pub mod serve;
pub mod solvers;
pub mod util;

/// Crate version (mirrors Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
