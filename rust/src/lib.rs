//! # SHINE — SHaring the INverse Estimate (ICLR 2022) reproduction
//!
//! A three-layer Rust + JAX + Pallas implementation of
//! *SHINE: SHaring the INverse Estimate from the forward pass for bi-level
//! optimization and implicit models* (Ramzi et al., ICLR 2022).
//!
//! Layers:
//! * **L3 (this crate)** — the coordinator: quasi-Newton solvers, the SHINE
//!   / Jacobian-Free / refine / fallback hypergradient strategies, the
//!   bi-level (HOAG-style) outer loop, the DEQ trainer, dataset generators,
//!   the experiment registry and the CLI.
//! * **L2 (python/compile/model.py)** — the DEQ compute graph in JAX,
//!   AOT-lowered once to HLO text artifacts.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the DEQ residual
//!   block and the low-rank (Sherman–Morrison) inverse application.
//!
//! The `runtime` module loads the artifacts through the PJRT C API (`xla`
//! crate); Python never runs on the experiment hot path.
//!
//! See DESIGN.md for the per-experiment index and EXPERIMENTS.md for
//! paper-vs-measured results.

pub mod bilevel;
pub mod coordinator;
pub mod data;
pub mod deq;
pub mod hypergrad;
pub mod linalg;
pub mod power;
pub mod runtime;
pub mod problems;
pub mod qn;
pub mod solvers;
pub mod util;

/// Crate version (mirrors Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
