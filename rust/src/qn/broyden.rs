//! Broyden's "good" method in inverse form — the DEQ forward solver.
//!
//! Maintains `H_n ≈ J⁻¹` directly via the Sherman–Morrison form used in the
//! Deep Equilibrium implementation of Bai et al.:
//!
//! ```text
//! H_{n+1} = H_n + (s_n − H_n y_n) (s_nᵀ H_n) / (s_nᵀ H_n y_n)
//! ```
//!
//! which keeps `H` as identity-plus-low-rank ([`LowRank`]), so both `H x`
//! (the forward step direction) and `Hᵀ x` (the SHINE backward direction)
//! are O(m·d). The matrix this represents satisfies the secant condition
//! `H_{n+1} y_n = s_n` — tested below against the dense update.
//!
//! Generic over the storage precision [`Elem`]: the DEQ trainer runs
//! `BroydenInverse<f32>` (half the panel traffic), the bi-level stack stays
//! on the `f64` default. The Sherman–Morrison denominator and the update
//! coefficients are always computed in f64.
//!
//! The hot-path entry points are [`BroydenInverse::update_ws`] and
//! [`BroydenInverse::direction_ws`]: all scratch comes from a
//! [`Workspace`], and the new factor is written straight into the panel
//! slots, so a solver iteration performs no heap allocation.

use crate::linalg::vecops::{dot, negate, nrm2, Elem};
use crate::qn::low_rank::LowRank;
use crate::qn::workspace::Workspace;
use crate::qn::{InvOp, MemoryPolicy};

#[derive(Clone, Debug)]
pub struct BroydenInverse<E: Elem = f64> {
    h: LowRank<E>,
    /// Guard for the Sherman–Morrison denominator `sᵀHy`.
    pub denom_eps: f64,
    /// Count of skipped (ill-conditioned) updates.
    pub skipped: usize,
}

impl<E: Elem> BroydenInverse<E> {
    pub fn new(dim: usize, max_mem: usize, policy: MemoryPolicy) -> Self {
        BroydenInverse {
            h: LowRank::identity(dim, max_mem, policy),
            denom_eps: 1e-10,
            skipped: 0,
        }
    }

    /// Start from an existing inverse estimate (the refine strategy warm
    /// starts the backward solver's qN matrix from the forward pass's).
    pub fn from_low_rank(h: LowRank<E>) -> Self {
        BroydenInverse {
            h,
            denom_eps: 1e-10,
            skipped: 0,
        }
    }

    pub fn dim(&self) -> usize {
        self.h.dim()
    }

    pub fn rank(&self) -> usize {
        self.h.rank()
    }

    /// Update with a step pair (s, y) = (z⁺ − z, g⁺ − g), drawing scratch
    /// from `ws`. Returns false if the update was skipped (tiny denominator
    /// or frozen). Allocation-free once `ws` is warm.
    pub fn update_ws(&mut self, s: &[E], y: &[E], ws: &mut Workspace<E>) -> bool {
        let d = s.len();
        let mut hy = ws.take(d);
        self.h.apply_into(y, &mut hy, ws);
        let denom = dot(s, &hy);
        // Scale-aware guard: compare against ‖s‖·‖Hy‖.
        if denom.abs() <= self.denom_eps * (nrm2(s) * nrm2(&hy)).max(1e-300) {
            self.skipped += 1;
            ws.give(hy);
            return false;
        }
        let mut sth = ws.take(d);
        self.h.apply_t_into(s, &mut sth, ws); // vᵀ = sᵀH  ⇔  v = Hᵀs
        let pushed = self.h.push_with(|u_slot, v_slot| {
            for i in 0..d {
                u_slot[i] = E::from_f64((s[i].to_f64() - hy[i].to_f64()) / denom);
            }
            v_slot.copy_from_slice(&sth);
        });
        ws.give(sth);
        ws.give(hy);
        pushed
    }

    /// Allocating convenience wrapper over [`BroydenInverse::update_ws`].
    pub fn update(&mut self, s: &[E], y: &[E]) -> bool {
        let mut ws = Workspace::new();
        self.update_ws(s, y, &mut ws)
    }

    /// The inverse estimate (for SHINE / refine warm starts).
    pub fn low_rank(&self) -> &LowRank<E> {
        &self.h
    }

    pub fn into_low_rank(self) -> LowRank<E> {
        self.h
    }

    /// Step direction p = −H g.
    pub fn direction(&self, g: &[E], out: &mut [E]) {
        self.h.apply(g, out);
        negate(out);
    }

    /// Step direction p = −H g with workspace scratch (allocation-free).
    pub fn direction_ws(&self, g: &[E], out: &mut [E], ws: &mut Workspace<E>) {
        self.h.apply_into(g, out, ws);
        negate(out);
    }
}

impl<E: Elem> InvOp<E> for BroydenInverse<E> {
    fn dim(&self) -> usize {
        self.h.dim()
    }
    fn apply(&self, x: &[E], out: &mut [E]) {
        self.h.apply(x, out)
    }
    fn apply_t(&self, x: &[E], out: &mut [E]) {
        self.h.apply_t(x, out)
    }
    fn apply_into(&self, x: &[E], out: &mut [E], ws: &mut Workspace<E>) {
        self.h.apply_into(x, out, ws)
    }
    fn apply_t_into(&self, x: &[E], out: &mut [E], ws: &mut Workspace<E>) {
        self.h.apply_t_into(x, out, ws)
    }
    fn apply_multi(&self, xs: &[E], out: &mut [E]) {
        self.h.apply_multi(xs, out)
    }
    fn apply_t_multi(&self, xs: &[E], out: &mut [E]) {
        self.h.apply_t_multi(xs, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn secant_condition_holds() {
        // After update(s, y): H y = s exactly.
        prop::check("broyden-secant", 25, |rng| {
            let n = 3 + rng.below(15);
            let mut b = BroydenInverse::new(n, 32, MemoryPolicy::Freeze);
            for _ in 0..5 {
                let s = rng.normal_vec(n);
                let y = rng.normal_vec(n);
                if b.update(&s, &y) {
                    let hy = b.apply_vec(&y);
                    prop::ensure_close_vec(&hy, &s, 1e-8, "secant Hy=s")?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn update_ws_matches_update() {
        prop::check("broyden-update-ws", 10, |rng| {
            let n = 6;
            let mut a = BroydenInverse::new(n, 16, MemoryPolicy::Freeze);
            let mut b = BroydenInverse::new(n, 16, MemoryPolicy::Freeze);
            let mut ws = Workspace::new();
            for _ in 0..5 {
                let s = rng.normal_vec(n);
                let y = rng.normal_vec(n);
                let ra = a.update(&s, &y);
                let rb = b.update_ws(&s, &y, &mut ws);
                prop::ensure(ra == rb, "same accept/skip decision")?;
            }
            let x = rng.normal_vec(n);
            prop::ensure_close_vec(&a.apply_vec(&x), &b.apply_vec(&x), 1e-14, "same operator")
        });
    }

    #[test]
    fn exact_on_linear_system_after_d_steps() {
        // For linear g(z) = A z − b, Broyden converges and H approximates A⁻¹
        // in the directions visited; the iteration must find the root.
        prop::check("broyden-linear", 10, |rng| {
            let n = 4 + rng.below(6);
            let a = crate::linalg::dmat::DMat::random_spd(n, 0.5, 3.0, rng);
            let x_star = rng.normal_vec(n);
            let mut b_vec = vec![0.0; n];
            a.matvec(&x_star, &mut b_vec);
            let g = |z: &[f64]| {
                let mut out = vec![0.0; n];
                a.matvec(z, &mut out);
                for i in 0..n {
                    out[i] -= b_vec[i];
                }
                out
            };
            let mut bro = BroydenInverse::new(n, 64, MemoryPolicy::Freeze);
            let mut z = vec![0.0; n];
            let mut gz = g(&z);
            let mut p = vec![0.0; n];
            for _ in 0..(4 * n) {
                bro.direction(&gz, &mut p);
                // Damped step for robustness on random conditioning.
                let mut z_new = z.clone();
                crate::linalg::vecops::axpy(1.0, &p, &mut z_new);
                let g_new = g(&z_new);
                let s: Vec<f64> = z_new.iter().zip(&z).map(|(a, b)| a - b).collect();
                let y: Vec<f64> = g_new.iter().zip(&gz).map(|(a, b)| a - b).collect();
                bro.update(&s, &y);
                z = z_new;
                gz = g_new;
                if nrm2(&gz) < 1e-10 {
                    break;
                }
            }
            prop::ensure(nrm2(&gz) < 1e-6, &format!("converged, |g|={}", nrm2(&gz)))
        });
    }

    #[test]
    fn skips_degenerate_updates() {
        let mut b: BroydenInverse = BroydenInverse::new(3, 8, MemoryPolicy::Freeze);
        // y such that H y ⟂ s → denominator 0 → skip.
        assert!(!b.update(&[1.0, 0.0, 0.0], &[0.0, 1.0, 0.0]));
        assert_eq!(b.skipped, 1);
        assert_eq!(b.rank(), 0);
    }

    #[test]
    fn transpose_apply_consistent() {
        prop::check("broyden-transpose", 10, |rng| {
            let n = 5;
            let mut b = BroydenInverse::new(n, 8, MemoryPolicy::Freeze);
            for _ in 0..4 {
                b.update(&rng.normal_vec(n), &rng.normal_vec(n));
            }
            // ⟨Hx, y⟩ == ⟨x, Hᵀy⟩ for all x, y.
            let x = rng.normal_vec(n);
            let y = rng.normal_vec(n);
            let lhs = dot(&b.apply_vec(&x), &y);
            let rhs = dot(&x, &b.apply_t_vec(&y));
            prop::ensure_close(lhs, rhs, 1e-10, "adjoint identity")
        });
    }

    #[test]
    fn apply_multi_matches_columnwise() {
        prop::check("broyden-multi", 8, |rng| {
            let n = 7;
            let k = 3;
            let mut b = BroydenInverse::new(n, 16, MemoryPolicy::Freeze);
            for _ in 0..5 {
                b.update(&rng.normal_vec(n), &rng.normal_vec(n));
            }
            let xs: Vec<f64> = (0..k * n).map(|_| rng.normal()).collect();
            let mut got = vec![0.0; k * n];
            b.apply_multi(&xs, &mut got);
            for r in 0..k {
                let want = b.apply_vec(&xs[r * n..(r + 1) * n]);
                prop::ensure_close_vec(&got[r * n..(r + 1) * n], &want, 1e-12, "multi col")?;
            }
            b.apply_t_multi(&xs, &mut got);
            for r in 0..k {
                let want = b.apply_t_vec(&xs[r * n..(r + 1) * n]);
                prop::ensure_close_vec(&got[r * n..(r + 1) * n], &want, 1e-12, "multi_t col")?;
            }
            Ok(())
        });
    }
}
