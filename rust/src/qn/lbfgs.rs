//! (Limited-memory) BFGS inverse-Hessian estimate with the paper's **OPA**
//! extra updates (Appendix A, Algorithm LBFGS; Theorem 3).
//!
//! The estimate is stored as the usual (s, y) pair history and applied with
//! the two-loop recursion, which realizes exactly the inverse-BFGS update
//!
//! ```text
//! H⁺ = (I − ρ s yᵀ) H (I − ρ y sᵀ) + ρ s sᵀ,   ρ = 1/(yᵀ s)
//! ```
//!
//! OPA inserts *extra* pairs `(e_n, ŷ_n)` with `e_n = t_n H ∂g/∂θ|_{z_n}`
//! and `ŷ_n = g(z_n + e_n) − g(z_n)` every `M` regular updates — improving
//! the approximation of `H` in precisely the direction the hypergradient
//! formula (3) needs. Extra updates change `H` but not the iterate `z_n`.

use crate::linalg::vecops::dot;
use crate::qn::InvOp;
use std::collections::VecDeque;

#[derive(Clone, Debug)]
struct Pair {
    s: Vec<f64>,
    y: Vec<f64>,
    rho: f64,
    /// true if this is an OPA extra update (kept distinct for diagnostics
    /// and for the paper's eviction rule which counts all updates).
    extra: bool,
}

/// Configuration of the OPA extra updates (Algorithm LBFGS inputs).
#[derive(Clone, Copy, Debug)]
pub struct OpaConfig {
    /// Apply an extra update every `freq` regular updates (M in the paper).
    pub freq: usize,
    /// t_0; subsequent t_n = ‖s_{n−1}‖ (the paper's suggested choice).
    pub t0: f64,
}

impl Default for OpaConfig {
    fn default() -> Self {
        OpaConfig { freq: 5, t0: 1.0 }
    }
}

#[derive(Clone, Debug)]
pub struct LbfgsInverse {
    dim: usize,
    max_mem: usize,
    pairs: VecDeque<Pair>,
    /// H₀ = gamma·I. The paper's theory takes B₀ = I (gamma = 1); classical
    /// L-BFGS uses the Barzilai–Borwein-style scaling. Both are supported;
    /// SHINE experiments default to 1 to match the theorems.
    pub gamma: f64,
    /// Curvature guard: pairs with yᵀs ≤ curvature_eps·‖y‖‖s‖ are rejected
    /// (the `r_n > 0` test in Algorithm LBFGS).
    pub curvature_eps: f64,
    pub skipped: usize,
    pub n_extra: usize,
}

impl LbfgsInverse {
    pub fn new(dim: usize, max_mem: usize) -> Self {
        LbfgsInverse {
            dim,
            max_mem,
            pairs: VecDeque::new(),
            gamma: 1.0,
            curvature_eps: 1e-12,
            skipped: 0,
            n_extra: 0,
        }
    }

    pub fn rank(&self) -> usize {
        self.pairs.len()
    }

    fn push(&mut self, s: Vec<f64>, y: Vec<f64>, extra: bool) -> bool {
        let sy = dot(&s, &y);
        let guard = self.curvature_eps
            * (crate::linalg::vecops::nrm2(&s) * crate::linalg::vecops::nrm2(&y)).max(1e-300);
        if sy <= guard {
            self.skipped += 1;
            return false;
        }
        if self.pairs.len() >= self.max_mem {
            // Paper's rule: "if n ≥ L remove update n − L" — drop the oldest.
            self.pairs.pop_front();
        }
        if extra {
            self.n_extra += 1;
        }
        self.pairs.push_back(Pair {
            rho: 1.0 / sy,
            s,
            y,
            extra,
        });
        true
    }

    /// Regular update from an accepted step.
    pub fn update(&mut self, s: &[f64], y: &[f64]) -> bool {
        self.push(s.to_vec(), y.to_vec(), false)
    }

    /// OPA extra update from the pair (e_n, ŷ_n). The caller (the solver
    /// driving g evaluations) computes ŷ_n = g(z+e) − g(z).
    pub fn update_extra(&mut self, e: &[f64], y_hat: &[f64]) -> bool {
        self.push(e.to_vec(), y_hat.to_vec(), true)
    }

    /// Number of stored pairs that are OPA extras.
    pub fn extra_pairs_stored(&self) -> usize {
        self.pairs.iter().filter(|p| p.extra).count()
    }

    /// Two-loop recursion: out = H x.
    fn two_loop(&self, x: &[f64], out: &mut [f64]) {
        let m = self.pairs.len();
        let mut q = x.to_vec();
        let mut alphas = vec![0.0; m];
        for (i, p) in self.pairs.iter().enumerate().rev() {
            let alpha = p.rho * dot(&p.s, &q);
            alphas[i] = alpha;
            for k in 0..self.dim {
                q[k] -= alpha * p.y[k];
            }
        }
        for v in q.iter_mut() {
            *v *= self.gamma;
        }
        for (i, p) in self.pairs.iter().enumerate() {
            let beta = p.rho * dot(&p.y, &q);
            let coeff = alphas[i] - beta;
            for k in 0..self.dim {
                q[k] += coeff * p.s[k];
            }
        }
        out.copy_from_slice(&q);
    }
}

impl InvOp for LbfgsInverse {
    fn dim(&self) -> usize {
        self.dim
    }
    fn apply(&self, x: &[f64], out: &mut [f64]) {
        self.two_loop(x, out)
    }
    /// BFGS inverse estimates are symmetric: Hᵀ = H.
    fn apply_t(&self, x: &[f64], out: &mut [f64]) {
        self.two_loop(x, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dmat::DMat;
    use crate::util::prop;

    /// Dense inverse-BFGS oracle: H⁺ = (I−ρsyᵀ) H (I−ρysᵀ) + ρssᵀ.
    fn dense_bfgs_update(h: &DMat, s: &[f64], y: &[f64]) -> DMat {
        let n = s.len();
        let rho = 1.0 / dot(s, y);
        let mut a = DMat::eye(n); // I − ρ s yᵀ
        for i in 0..n {
            for j in 0..n {
                a[(i, j)] -= rho * s[i] * y[j];
            }
        }
        let mut out = a.matmul(h).matmul(&a.transpose());
        for i in 0..n {
            for j in 0..n {
                out[(i, j)] += rho * s[i] * s[j];
            }
        }
        out
    }

    #[test]
    fn two_loop_matches_dense_oracle() {
        prop::check("lbfgs-dense-oracle", 15, |rng| {
            let n = 3 + rng.below(10);
            let mut lb = LbfgsInverse::new(n, 64);
            let mut h = DMat::eye(n);
            for _ in 0..6 {
                let s = rng.normal_vec(n);
                // Force curvature: y = s + small noise keeps yᵀs > 0 mostly.
                let mut y = rng.normal_vec(n);
                if dot(&s, &y) <= 0.0 {
                    for k in 0..n {
                        y[k] = -y[k];
                    }
                }
                if lb.update(&s, &y) {
                    h = dense_bfgs_update(&h, &s, &y);
                }
            }
            let x = rng.normal_vec(n);
            let mut want = vec![0.0; n];
            h.matvec(&x, &mut want);
            prop::ensure_close_vec(&lb.apply_vec(&x), &want, 1e-8, "two-loop vs dense")
        });
    }

    #[test]
    fn secant_condition_on_last_pair() {
        prop::check("lbfgs-secant", 15, |rng| {
            let n = 4 + rng.below(8);
            let mut lb = LbfgsInverse::new(n, 64);
            let mut last: Option<(Vec<f64>, Vec<f64>)> = None;
            for _ in 0..5 {
                let s = rng.normal_vec(n);
                let mut y = rng.normal_vec(n);
                if dot(&s, &y) <= 0.0 {
                    for v in y.iter_mut() {
                        *v = -*v;
                    }
                }
                if lb.update(&s, &y) {
                    last = Some((s, y));
                }
            }
            if let Some((s, y)) = last {
                prop::ensure_close_vec(&lb.apply_vec(&y), &s, 1e-8, "H y = s")?;
            }
            Ok(())
        });
    }

    #[test]
    fn rejects_nonpositive_curvature() {
        let mut lb = LbfgsInverse::new(3, 8);
        let s = vec![1.0, 0.0, 0.0];
        let y = vec![-1.0, 0.0, 0.0]; // yᵀs < 0
        assert!(!lb.update(&s, &y));
        assert_eq!(lb.skipped, 1);
        assert_eq!(lb.rank(), 0);
    }

    #[test]
    fn positive_definite_with_positive_curvature() {
        prop::check("lbfgs-pd", 15, |rng| {
            let n = 5;
            let mut lb = LbfgsInverse::new(n, 16);
            for _ in 0..6 {
                let s = rng.normal_vec(n);
                let mut y = rng.normal_vec(n);
                if dot(&s, &y) <= 0.0 {
                    for v in y.iter_mut() {
                        *v = -*v;
                    }
                }
                lb.update(&s, &y);
            }
            let x = rng.normal_vec(n);
            let hx = lb.apply_vec(&x);
            prop::ensure(dot(&x, &hx) > 0.0, "xᵀHx > 0")
        });
    }

    #[test]
    fn memory_eviction() {
        let n = 4;
        let mut lb = LbfgsInverse::new(n, 2);
        for i in 0..5 {
            let mut s = vec![0.0; n];
            s[i % n] = 1.0;
            let y = s.clone();
            lb.update(&s, &y);
        }
        assert_eq!(lb.rank(), 2);
    }

    #[test]
    fn extra_updates_counted() {
        let mut lb = LbfgsInverse::new(3, 8);
        lb.update(&[1.0, 0.0, 0.0], &[1.0, 0.0, 0.0]);
        lb.update_extra(&[0.0, 1.0, 0.0], &[0.0, 2.0, 0.0]);
        assert_eq!(lb.n_extra, 1);
        assert_eq!(lb.extra_pairs_stored(), 1);
        assert_eq!(lb.rank(), 2);
    }

    #[test]
    fn symmetric_apply() {
        prop::check("lbfgs-symmetric", 10, |rng| {
            let n = 6;
            let mut lb = LbfgsInverse::new(n, 8);
            for _ in 0..4 {
                let s = rng.normal_vec(n);
                let mut y = rng.normal_vec(n);
                if dot(&s, &y) <= 0.0 {
                    for v in y.iter_mut() {
                        *v = -*v;
                    }
                }
                lb.update(&s, &y);
            }
            let x = rng.normal_vec(n);
            let y = rng.normal_vec(n);
            prop::ensure_close(
                dot(&lb.apply_vec(&x), &y),
                dot(&x, &lb.apply_vec(&y)),
                1e-10,
                "symmetry",
            )
        });
    }
}
