//! (Limited-memory) BFGS inverse-Hessian estimate with the paper's **OPA**
//! extra updates (Appendix A, Algorithm LBFGS; Theorem 3).
//!
//! The estimate is stored as the usual (s, y) pair history and applied with
//! the two-loop recursion, which realizes exactly the inverse-BFGS update
//!
//! ```text
//! H⁺ = (I − ρ s yᵀ) H (I − ρ y sᵀ) + ρ s sᵀ,   ρ = 1/(yᵀ s)
//! ```
//!
//! OPA inserts *extra* pairs `(e_n, ŷ_n)` with `e_n = t_n H ∂g/∂θ|_{z_n}`
//! and `ŷ_n = g(z_n + e_n) − g(z_n)` every `M` regular updates — improving
//! the approximation of `H` in precisely the direction the hypergradient
//! formula (3) needs. Extra updates change `H` but not the iterate `z_n`.
//!
//! The (s, y) history lives in a [`FactorPanel<E>`] (u-rows = s, v-rows = y)
//! with per-slot `ρ` and OPA flags in parallel rings, so accepting an update
//! writes panel slots in place (O(1) eviction, zero allocation) and the
//! two-loop recursion streams contiguous rows. Per the [`Elem`] contract the
//! pair history is stored in `E` while `ρ`, the curvature guard, and the
//! two-loop α/β coefficients stay f64. [`InvOp::apply_into`] draws its two
//! scratch vectors from a [`Workspace`] (`q` in storage precision, α's from
//! the accumulator pool).

use crate::linalg::vecops::{axpy, dot, nrm2, scale, Elem};
use crate::qn::panel::FactorPanel;
use crate::qn::workspace::Workspace;
use crate::qn::InvOp;

/// Configuration of the OPA extra updates (Algorithm LBFGS inputs).
#[derive(Clone, Copy, Debug)]
pub struct OpaConfig {
    /// Apply an extra update every `freq` regular updates (M in the paper).
    pub freq: usize,
    /// t_0; subsequent t_n = ‖s_{n−1}‖ (the paper's suggested choice).
    pub t0: f64,
}

impl Default for OpaConfig {
    fn default() -> Self {
        OpaConfig { freq: 5, t0: 1.0 }
    }
}

#[derive(Clone, Debug)]
pub struct LbfgsInverse<E: Elem = f64> {
    dim: usize,
    /// (s, y) pair history: panel u-rows are s, v-rows are y.
    pairs: FactorPanel<E>,
    /// ρ = 1/(yᵀs) per pair, indexed by *physical* panel row. Kept in f64
    /// for both storage precisions (it is a reduction result).
    rho: Vec<f64>,
    /// OPA-extra flag per pair, indexed by physical panel row (kept distinct
    /// for diagnostics; the paper's eviction rule counts all updates).
    extra: Vec<bool>,
    /// H₀ = gamma·I. The paper's theory takes B₀ = I (gamma = 1); classical
    /// L-BFGS uses the Barzilai–Borwein-style scaling. Both are supported;
    /// SHINE experiments default to 1 to match the theorems.
    pub gamma: f64,
    /// Curvature guard: pairs with yᵀs ≤ curvature_eps·‖y‖‖s‖ are rejected
    /// (the `r_n > 0` test in Algorithm LBFGS).
    pub curvature_eps: f64,
    pub skipped: usize,
    pub n_extra: usize,
}

impl<E: Elem> LbfgsInverse<E> {
    pub fn new(dim: usize, max_mem: usize) -> Self {
        LbfgsInverse {
            dim,
            pairs: FactorPanel::new(dim, max_mem),
            rho: vec![0.0; max_mem],
            extra: vec![false; max_mem],
            gamma: 1.0,
            curvature_eps: 1e-12,
            skipped: 0,
            n_extra: 0,
        }
    }

    pub fn rank(&self) -> usize {
        self.pairs.len()
    }

    fn push(&mut self, s: &[E], y: &[E], extra: bool) -> bool {
        let sy = dot(s, y);
        let guard = self.curvature_eps * (nrm2(s) * nrm2(y)).max(1e-300);
        if sy <= guard {
            self.skipped += 1;
            return false;
        }
        // Paper's rule: "if n ≥ L remove update n − L" — the panel ring
        // drops the oldest pair in O(1) when full.
        let (phys, s_slot, y_slot) = self.pairs.advance();
        s_slot.copy_from_slice(s);
        y_slot.copy_from_slice(y);
        self.rho[phys] = 1.0 / sy;
        self.extra[phys] = extra;
        if extra {
            self.n_extra += 1;
        }
        true
    }

    /// Regular update from an accepted step. Allocation-free: the pair is
    /// copied straight into the panel slots.
    pub fn update(&mut self, s: &[E], y: &[E]) -> bool {
        self.push(s, y, false)
    }

    /// OPA extra update from the pair (e_n, ŷ_n). The caller (the solver
    /// driving g evaluations) computes ŷ_n = g(z+e) − g(z).
    pub fn update_extra(&mut self, e: &[E], y_hat: &[E]) -> bool {
        self.push(e, y_hat, true)
    }

    /// Number of stored pairs that are OPA extras.
    pub fn extra_pairs_stored(&self) -> usize {
        (0..self.pairs.len())
            .filter(|&i| self.extra[self.pairs.phys(i)])
            .count()
    }

    /// Two-loop recursion: out = H x, with `q`/`alphas` scratch provided by
    /// the caller (q: dim, alphas: ≥ rank; α's are f64 — reduction results).
    fn two_loop_into(&self, x: &[E], out: &mut [E], q: &mut [E], alphas: &mut [f64]) {
        let m = self.pairs.len();
        q.copy_from_slice(x);
        for i in (0..m).rev() {
            let (s, y) = self.pairs.row(i);
            let alpha = self.rho[self.pairs.phys(i)] * dot(s, q);
            alphas[i] = alpha;
            axpy(-alpha, y, q);
        }
        scale(self.gamma, q);
        for i in 0..m {
            let (s, y) = self.pairs.row(i);
            let beta = self.rho[self.pairs.phys(i)] * dot(y, q);
            axpy(alphas[i] - beta, s, q);
        }
        out.copy_from_slice(q);
    }
}

impl<E: Elem> InvOp<E> for LbfgsInverse<E> {
    fn dim(&self) -> usize {
        self.dim
    }
    fn apply(&self, x: &[E], out: &mut [E]) {
        let mut q = vec![E::ZERO; self.dim];
        let mut alphas = vec![0.0f64; self.pairs.len()];
        self.two_loop_into(x, out, &mut q, &mut alphas);
    }
    /// BFGS inverse estimates are symmetric: Hᵀ = H.
    fn apply_t(&self, x: &[E], out: &mut [E]) {
        self.apply(x, out);
    }
    fn apply_into(&self, x: &[E], out: &mut [E], ws: &mut Workspace<E>) {
        let mut q = ws.take(self.dim);
        // Power-of-two-quantized take keeps the workspace buffer size stable
        // while the history fills.
        let mut alphas = ws.take_acc(self.pairs.coeff_len());
        self.two_loop_into(x, out, &mut q, &mut alphas);
        ws.give_acc(alphas);
        ws.give(q);
    }
    fn apply_t_into(&self, x: &[E], out: &mut [E], ws: &mut Workspace<E>) {
        self.apply_into(x, out, ws);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dmat::DMat;
    use crate::util::prop;

    /// Dense inverse-BFGS oracle: H⁺ = (I−ρsyᵀ) H (I−ρysᵀ) + ρssᵀ.
    fn dense_bfgs_update(h: &DMat, s: &[f64], y: &[f64]) -> DMat {
        let n = s.len();
        let rho = 1.0 / dot(s, y);
        let mut a = DMat::eye(n); // I − ρ s yᵀ
        for i in 0..n {
            for j in 0..n {
                a[(i, j)] -= rho * s[i] * y[j];
            }
        }
        let mut out = a.matmul(h).matmul(&a.transpose());
        for i in 0..n {
            for j in 0..n {
                out[(i, j)] += rho * s[i] * s[j];
            }
        }
        out
    }

    #[test]
    fn two_loop_matches_dense_oracle() {
        prop::check("lbfgs-dense-oracle", 15, |rng| {
            let n = 3 + rng.below(10);
            let mut lb = LbfgsInverse::new(n, 64);
            let mut h = DMat::eye(n);
            for _ in 0..6 {
                let s = rng.normal_vec(n);
                // Force curvature: y = s + small noise keeps yᵀs > 0 mostly.
                let mut y = rng.normal_vec(n);
                if dot(&s, &y) <= 0.0 {
                    for k in 0..n {
                        y[k] = -y[k];
                    }
                }
                if lb.update(&s, &y) {
                    h = dense_bfgs_update(&h, &s, &y);
                }
            }
            let x = rng.normal_vec(n);
            let mut want = vec![0.0; n];
            h.matvec(&x, &mut want);
            prop::ensure_close_vec(&lb.apply_vec(&x), &want, 1e-8, "two-loop vs dense")
        });
    }

    #[test]
    fn secant_condition_on_last_pair() {
        prop::check("lbfgs-secant", 15, |rng| {
            let n = 4 + rng.below(8);
            let mut lb = LbfgsInverse::new(n, 64);
            let mut last: Option<(Vec<f64>, Vec<f64>)> = None;
            for _ in 0..5 {
                let s = rng.normal_vec(n);
                let mut y = rng.normal_vec(n);
                if dot(&s, &y) <= 0.0 {
                    for v in y.iter_mut() {
                        *v = -*v;
                    }
                }
                if lb.update(&s, &y) {
                    last = Some((s, y));
                }
            }
            if let Some((s, y)) = last {
                prop::ensure_close_vec(&lb.apply_vec(&y), &s, 1e-8, "H y = s")?;
            }
            Ok(())
        });
    }

    #[test]
    fn adjoint_identity() {
        // ⟨Hx, y⟩ == ⟨x, Hᵀy⟩ — trivially from symmetry for BFGS, but the
        // property pins the InvOp contract for all qN families alike.
        prop::check("lbfgs-adjoint", 15, |rng| {
            let n = 4 + rng.below(10);
            let mut lb = LbfgsInverse::new(n, 8);
            for _ in 0..6 {
                let s = rng.normal_vec(n);
                let mut y = rng.normal_vec(n);
                if dot(&s, &y) <= 0.0 {
                    for v in y.iter_mut() {
                        *v = -*v;
                    }
                }
                lb.update(&s, &y);
            }
            let x = rng.normal_vec(n);
            let y = rng.normal_vec(n);
            let lhs = dot(&lb.apply_vec(&x), &y);
            let rhs = dot(&x, &lb.apply_t_vec(&y));
            prop::ensure_close(lhs, rhs, 1e-10, "adjoint identity")
        });
    }

    #[test]
    fn apply_into_matches_apply() {
        let mut rng = crate::util::rng::Rng::new(31);
        let n = 10;
        let mut lb = LbfgsInverse::new(n, 4);
        for _ in 0..7 {
            let s = rng.normal_vec(n);
            let mut y = rng.normal_vec(n);
            if dot(&s, &y) <= 0.0 {
                for v in y.iter_mut() {
                    *v = -*v;
                }
            }
            lb.update(&s, &y);
        }
        let x = rng.normal_vec(n);
        let mut ws = Workspace::new();
        let mut got = vec![0.0; n];
        lb.apply_into(&x, &mut got, &mut ws);
        assert_eq!(got, lb.apply_vec(&x));
    }

    #[test]
    fn apply_multi_matches_columnwise() {
        prop::check("lbfgs-multi", 8, |rng| {
            let n = 6;
            let k = 4;
            let mut lb = LbfgsInverse::new(n, 8);
            for _ in 0..5 {
                let s = rng.normal_vec(n);
                let mut y = rng.normal_vec(n);
                if dot(&s, &y) <= 0.0 {
                    for v in y.iter_mut() {
                        *v = -*v;
                    }
                }
                lb.update(&s, &y);
            }
            let xs: Vec<f64> = (0..k * n).map(|_| rng.normal()).collect();
            let mut got = vec![0.0; k * n];
            lb.apply_multi(&xs, &mut got);
            for r in 0..k {
                let want = lb.apply_vec(&xs[r * n..(r + 1) * n]);
                prop::ensure_close_vec(&got[r * n..(r + 1) * n], &want, 1e-12, "multi col")?;
            }
            Ok(())
        });
    }

    #[test]
    fn rejects_nonpositive_curvature() {
        let mut lb = LbfgsInverse::new(3, 8);
        let s = vec![1.0, 0.0, 0.0];
        let y = vec![-1.0, 0.0, 0.0]; // yᵀs < 0
        assert!(!lb.update(&s, &y));
        assert_eq!(lb.skipped, 1);
        assert_eq!(lb.rank(), 0);
    }

    #[test]
    fn positive_definite_with_positive_curvature() {
        prop::check("lbfgs-pd", 15, |rng| {
            let n = 5;
            let mut lb = LbfgsInverse::new(n, 16);
            for _ in 0..6 {
                let s = rng.normal_vec(n);
                let mut y = rng.normal_vec(n);
                if dot(&s, &y) <= 0.0 {
                    for v in y.iter_mut() {
                        *v = -*v;
                    }
                }
                lb.update(&s, &y);
            }
            let x = rng.normal_vec(n);
            let hx = lb.apply_vec(&x);
            prop::ensure(dot(&x, &hx) > 0.0, "xᵀHx > 0")
        });
    }

    #[test]
    fn memory_eviction() {
        let n = 4;
        let mut lb = LbfgsInverse::new(n, 2);
        for i in 0..5 {
            let mut s = vec![0.0; n];
            s[i % n] = 1.0;
            let y = s.clone();
            lb.update(&s, &y);
        }
        assert_eq!(lb.rank(), 2);
    }

    #[test]
    fn eviction_matches_dense_on_survivors() {
        // The ring-buffer eviction must behave exactly like rebuilding the
        // estimate from the newest `mem` accepted pairs.
        prop::check("lbfgs-evict-dense", 10, |rng| {
            let n = 5;
            let mem = 3;
            let mut lb = LbfgsInverse::new(n, mem);
            let mut accepted: Vec<(Vec<f64>, Vec<f64>)> = Vec::new();
            for _ in 0..8 {
                let s = rng.normal_vec(n);
                let mut y = rng.normal_vec(n);
                if dot(&s, &y) <= 0.0 {
                    for v in y.iter_mut() {
                        *v = -*v;
                    }
                }
                if lb.update(&s, &y) {
                    accepted.push((s, y));
                }
            }
            let start = accepted.len().saturating_sub(mem);
            let mut h = DMat::eye(n);
            for (s, y) in &accepted[start..] {
                h = dense_bfgs_update(&h, s, y);
            }
            let x = rng.normal_vec(n);
            let mut want = vec![0.0; n];
            h.matvec(&x, &mut want);
            prop::ensure_close_vec(&lb.apply_vec(&x), &want, 1e-8, "evicted two-loop vs dense")
        });
    }

    #[test]
    fn extra_updates_counted() {
        let mut lb = LbfgsInverse::new(3, 8);
        lb.update(&[1.0, 0.0, 0.0], &[1.0, 0.0, 0.0]);
        lb.update_extra(&[0.0, 1.0, 0.0], &[0.0, 2.0, 0.0]);
        assert_eq!(lb.n_extra, 1);
        assert_eq!(lb.extra_pairs_stored(), 1);
        assert_eq!(lb.rank(), 2);
    }

    #[test]
    fn symmetric_apply() {
        prop::check("lbfgs-symmetric", 10, |rng| {
            let n = 6;
            let mut lb = LbfgsInverse::new(n, 8);
            for _ in 0..4 {
                let s = rng.normal_vec(n);
                let mut y = rng.normal_vec(n);
                if dot(&s, &y) <= 0.0 {
                    for v in y.iter_mut() {
                        *v = -*v;
                    }
                }
                lb.update(&s, &y);
            }
            let x = rng.normal_vec(n);
            let y = rng.normal_vec(n);
            prop::ensure_close(
                dot(&lb.apply_vec(&x), &y),
                dot(&x, &lb.apply_vec(&y)),
                1e-10,
                "symmetry",
            )
        });
    }
}
