//! Limited-memory low-rank representation `H = I + Σᵢ uᵢ vᵢᵀ`, generic over
//! the storage precision.
//!
//! Both Broyden's inverse form and the Sherman–Morrison-maintained inverse of
//! the Adjoint Broyden matrix live in this structure. Applying `H` or `Hᵀ`
//! costs `O(m·d)` — this is exactly why SHINE's backward pass is ~10× cheaper
//! than the iterative inversion (Fig. 3, Table E.2).
//!
//! The factors live in two flat row-major panels
//! ([`crate::qn::panel::FactorPanel`]): `H x` is a two-phase blocked
//! kernel — the coefficient sweep `c = V x` ([`panel_gemv`], f64
//! coefficients) followed by the accumulation sweep `out = x + Uᵀ c`
//! ([`panel_gemv_t`]) — parallelized over row/column chunks with
//! [`crate::util::threads::par_chunks_mut`] once the panel exceeds
//! [`PAR_MIN_ELEMS`]. Eviction is O(1) (ring rotation), and
//! [`LowRank::push_with`] fills the new factor's panel slots in place so
//! solver loops never allocate. At `E = f32` the sweeps move half the bytes
//! of the f64 instantiation while every dot still accumulates in f64 (the
//! [`Elem`] contract).

use crate::linalg::vecops::{
    axpy, panel_gemv, panel_gemv_multi, panel_gemv_t, panel_gemv_t_multi, Elem,
};
use crate::qn::panel::FactorPanel;
use crate::qn::workspace::Workspace;
use crate::qn::{InvOp, MemoryPolicy};
use crate::util::threads;

/// Re-export of the kernel threading threshold (the constant moved to
/// [`crate::linalg::vecops`] when the multi-RHS kernels grew their own
/// thread paths; this alias keeps the historical `qn::low_rank` path alive).
pub use crate::linalg::vecops::PAR_MIN_ELEMS;

#[derive(Clone, Debug)]
pub struct LowRank<E: Elem = f64> {
    panel: FactorPanel<E>,
    policy: MemoryPolicy,
    /// Number of updates rejected because the buffer was frozen.
    pub frozen_rejects: usize,
}

impl<E: Elem> LowRank<E> {
    pub fn identity(dim: usize, max_mem: usize, policy: MemoryPolicy) -> Self {
        LowRank {
            panel: FactorPanel::new(dim, max_mem),
            policy,
            frozen_rejects: 0,
        }
    }

    pub fn rank(&self) -> usize {
        self.panel.len()
    }

    pub fn max_mem(&self) -> usize {
        self.panel.cap()
    }

    pub fn is_full(&self) -> bool {
        self.panel.is_full()
    }

    pub fn policy(&self) -> MemoryPolicy {
        self.policy
    }

    /// Append a rank-one term `u vᵀ`, filling the panel slots through
    /// `fill(u_slot, v_slot)` — no intermediate allocation. Under
    /// [`MemoryPolicy::Evict`] a full buffer drops its oldest factor in O(1);
    /// under [`MemoryPolicy::Freeze`] the update is rejected (returns false)
    /// and `fill` is never called.
    pub fn push_with(&mut self, fill: impl FnOnce(&mut [E], &mut [E])) -> bool {
        if self.panel.is_full() && self.policy == MemoryPolicy::Freeze {
            self.frozen_rejects += 1;
            return false;
        }
        let (_, us, vs) = self.panel.advance();
        fill(us, vs);
        true
    }

    /// Append a rank-one term `u vᵀ`. Returns false if frozen-full.
    pub fn push(&mut self, u: &[E], v: &[E]) -> bool {
        debug_assert_eq!(u.len(), self.panel.dim());
        debug_assert_eq!(v.len(), self.panel.dim());
        self.push_with(|us, vs| {
            us.copy_from_slice(u);
            vs.copy_from_slice(v);
        })
    }

    /// Factor pairs in logical (oldest → newest) order. Direct access for
    /// warm-starting a backward solver from the forward estimate (the
    /// *refine* strategy) and for dense test oracles.
    pub fn rows(&self) -> impl Iterator<Item = (&[E], &[E])> + '_ {
        self.panel.rows()
    }

    pub fn clear(&mut self) {
        self.panel.clear();
        self.frozen_rejects = 0;
    }

    /// Zero-copy view of the transposed operator
    /// `(I + Σ u vᵀ)ᵀ = I + Σ v uᵀ` — apply/apply_t swapped, no storage
    /// touched. Use when the backward pass only needs to *apply* `Hᵀ`.
    pub fn t(&self) -> TransposedView<'_, E> {
        TransposedView(self)
    }

    /// Consume into the transposed operator by swapping the u/v panels —
    /// O(1), no copies. Use (after a clone when the forward estimate must be
    /// retained) when the transposed matrix seeds a solver that will push
    /// further updates, e.g. the refine strategy's warm-started backward
    /// Broyden.
    pub fn into_transposed(mut self) -> LowRank<E> {
        self.panel.swap_uv();
        self
    }

    /// Grow/shrink the memory budget (refine adds room for new updates on
    /// top of the forward estimate). Keeps the newest factors on shrink;
    /// growing an unwrapped (Freeze-built) estimate is O(1).
    pub fn with_max_mem(mut self, max_mem: usize, policy: MemoryPolicy) -> LowRank<E> {
        self.panel.resize_cap(max_mem);
        self.policy = policy;
        self
    }

    /// Pack factors into flat row-major (m, d) buffers in logical order —
    /// the layout the `lowrank_apply` Pallas artifact consumes.
    pub fn pack(&self) -> (Vec<E>, Vec<E>) {
        let d = self.panel.dim();
        let mut u = Vec::with_capacity(self.rank() * d);
        let mut v = Vec::with_capacity(self.rank() * d);
        for (ur, vr) in self.rows() {
            u.extend_from_slice(ur);
            v.extend_from_slice(vr);
        }
        (u, v)
    }

    /// Two-phase blocked kernel shared by apply/apply_t: with
    /// `transpose == false` computes `out = x + Uᵀ (V x)`, with `true` the
    /// roles of the panels swap. `coeffs` must hold at least `rank()` f64
    /// slots (coefficients are reduction results and stay in accumulator
    /// precision).
    fn apply_impl(&self, transpose: bool, x: &[E], out: &mut [E], coeffs: &mut [f64]) {
        out.copy_from_slice(x);
        let m = self.panel.len();
        if m == 0 {
            return;
        }
        let d = self.panel.dim();
        let (coef_panel, acc_panel) = if transpose {
            (self.panel.u_flat(), self.panel.v_flat())
        } else {
            (self.panel.v_flat(), self.panel.u_flat())
        };
        let coeffs = &mut coeffs[..m];
        if m * d < PAR_MIN_ELEMS {
            panel_gemv(coef_panel, m, d, x, coeffs);
            panel_gemv_t(acc_panel, m, d, coeffs, out);
        } else {
            let workers = threads::ncpus().min(16);
            threads::par_chunks_mut(&mut coeffs[..], workers.min(m), |off, cc| {
                panel_gemv(&coef_panel[off * d..], cc.len(), d, x, cc);
            });
            let coeffs: &[f64] = coeffs;
            threads::par_chunks_mut(&mut out[..], workers, |off, oc| {
                for (i, &c) in coeffs.iter().enumerate() {
                    if c != 0.0 {
                        axpy(c, &acc_panel[i * d + off..i * d + off + oc.len()], oc);
                    }
                }
            });
        }
    }

    /// Shared multi-RHS kernel: one coefficient sweep and one accumulation
    /// sweep over the panels serve all `k` right-hand sides (`xs`, `out` are
    /// row-major `k × d`); `coeffs` must hold at least `rank() · k` f64
    /// slots. The sweeps themselves shard across threads above
    /// [`PAR_MIN_ELEMS`] (see [`panel_gemv_multi`] / [`panel_gemv_t_multi`]).
    fn apply_multi_impl(&self, transpose: bool, xs: &[E], out: &mut [E], coeffs: &mut [f64]) {
        out.copy_from_slice(xs);
        let m = self.panel.len();
        if m == 0 {
            return;
        }
        let d = self.panel.dim();
        let k = xs.len() / d;
        debug_assert_eq!(xs.len(), k * d);
        let (coef_panel, acc_panel) = if transpose {
            (self.panel.u_flat(), self.panel.v_flat())
        } else {
            (self.panel.v_flat(), self.panel.u_flat())
        };
        let coeffs = &mut coeffs[..m * k];
        panel_gemv_multi(coef_panel, m, d, xs, k, coeffs);
        panel_gemv_t_multi(acc_panel, m, d, coeffs, k, out);
    }

    /// Right-hand-side count of a multi-RHS call (`xs.len() / dim`, robust
    /// to the empty-panel case the kernels early-return on).
    fn multi_k(&self, xs: &[E]) -> usize {
        let d = self.panel.dim();
        if d == 0 {
            0
        } else {
            xs.len() / d
        }
    }
}

impl<E: Elem> InvOp<E> for LowRank<E> {
    fn dim(&self) -> usize {
        self.panel.dim()
    }

    fn apply(&self, x: &[E], out: &mut [E]) {
        let mut coeffs = vec![0.0f64; self.panel.len()];
        self.apply_impl(false, x, out, &mut coeffs);
    }

    fn apply_t(&self, x: &[E], out: &mut [E]) {
        let mut coeffs = vec![0.0f64; self.panel.len()];
        self.apply_impl(true, x, out, &mut coeffs);
    }

    fn apply_into(&self, x: &[E], out: &mut [E], ws: &mut Workspace<E>) {
        // Power-of-two-quantized coefficient buffer: its size stays stable
        // while the rank grows, so the workspace stops reallocating after the
        // first few iterations of a solver run.
        let mut coeffs = ws.take_acc(self.panel.coeff_len());
        self.apply_impl(false, x, out, &mut coeffs);
        ws.give_acc(coeffs);
    }

    fn apply_t_into(&self, x: &[E], out: &mut [E], ws: &mut Workspace<E>) {
        let mut coeffs = ws.take_acc(self.panel.coeff_len());
        self.apply_impl(true, x, out, &mut coeffs);
        ws.give_acc(coeffs);
    }

    fn apply_multi(&self, xs: &[E], out: &mut [E]) {
        let mut coeffs = vec![0.0f64; self.panel.len() * self.multi_k(xs)];
        self.apply_multi_impl(false, xs, out, &mut coeffs);
    }

    fn apply_t_multi(&self, xs: &[E], out: &mut [E]) {
        let mut coeffs = vec![0.0f64; self.panel.len() * self.multi_k(xs)];
        self.apply_multi_impl(true, xs, out, &mut coeffs);
    }

    fn apply_multi_into(&self, xs: &[E], out: &mut [E], ws: &mut Workspace<E>) {
        // coeff_len-quantized block: stable take size while the rank grows,
        // so the serving loop's per-batch takes never reallocate.
        let mut coeffs = ws.take_acc(self.panel.coeff_len() * self.multi_k(xs));
        self.apply_multi_impl(false, xs, out, &mut coeffs);
        ws.give_acc(coeffs);
    }

    fn apply_t_multi_into(&self, xs: &[E], out: &mut [E], ws: &mut Workspace<E>) {
        let mut coeffs = ws.take_acc(self.panel.coeff_len() * self.multi_k(xs));
        self.apply_multi_impl(true, xs, out, &mut coeffs);
        ws.give_acc(coeffs);
    }
}

/// Zero-copy transposed view of a [`LowRank`]: `apply` and `apply_t` swap.
/// Created by [`LowRank::t`].
pub struct TransposedView<'a, E: Elem = f64>(&'a LowRank<E>);

impl<E: Elem> InvOp<E> for TransposedView<'_, E> {
    fn dim(&self) -> usize {
        InvOp::dim(self.0)
    }
    fn apply(&self, x: &[E], out: &mut [E]) {
        self.0.apply_t(x, out)
    }
    fn apply_t(&self, x: &[E], out: &mut [E]) {
        self.0.apply(x, out)
    }
    fn apply_into(&self, x: &[E], out: &mut [E], ws: &mut Workspace<E>) {
        self.0.apply_t_into(x, out, ws)
    }
    fn apply_t_into(&self, x: &[E], out: &mut [E], ws: &mut Workspace<E>) {
        self.0.apply_into(x, out, ws)
    }
    fn apply_multi(&self, xs: &[E], out: &mut [E]) {
        self.0.apply_t_multi(xs, out)
    }
    fn apply_t_multi(&self, xs: &[E], out: &mut [E]) {
        self.0.apply_multi(xs, out)
    }
    fn apply_multi_into(&self, xs: &[E], out: &mut [E], ws: &mut Workspace<E>) {
        self.0.apply_t_multi_into(xs, out, ws)
    }
    fn apply_t_multi_into(&self, xs: &[E], out: &mut [E], ws: &mut Workspace<E>) {
        self.0.apply_multi_into(xs, out, ws)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dmat::DMat;
    use crate::util::prop;
    use crate::util::rng::Rng;

    /// Dense materialization for oracle comparison.
    fn dense(lr: &LowRank) -> DMat {
        let n = InvOp::dim(lr);
        let mut m = DMat::eye(n);
        for (u, v) in lr.rows() {
            for i in 0..n {
                for j in 0..n {
                    m[(i, j)] += u[i] * v[j];
                }
            }
        }
        m
    }

    #[test]
    fn apply_matches_dense() {
        prop::check("lowrank-apply", 20, |rng| {
            let n = 3 + rng.below(20);
            let mut lr = LowRank::identity(n, 10, MemoryPolicy::Evict);
            for _ in 0..rng.below(8) {
                lr.push(&rng.normal_vec(n), &rng.normal_vec(n));
            }
            let d = dense(&lr);
            let x = rng.normal_vec(n);
            let mut want = vec![0.0; n];
            d.matvec(&x, &mut want);
            prop::ensure_close_vec(&lr.apply_vec(&x), &want, 1e-10, "apply")?;
            d.matvec_t(&x, &mut want);
            prop::ensure_close_vec(&lr.apply_t_vec(&x), &want, 1e-10, "apply_t")
        });
    }

    #[test]
    fn apply_into_matches_apply() {
        let mut rng = Rng::new(17);
        let n = 12;
        let mut lr = LowRank::identity(n, 6, MemoryPolicy::Evict);
        for _ in 0..9 {
            lr.push(&rng.normal_vec(n), &rng.normal_vec(n));
        }
        let x = rng.normal_vec(n);
        let mut ws = Workspace::new();
        let mut got = vec![0.0; n];
        lr.apply_into(&x, &mut got, &mut ws);
        assert_eq!(got, lr.apply_vec(&x));
        lr.apply_t_into(&x, &mut got, &mut ws);
        assert_eq!(got, lr.apply_t_vec(&x));
    }

    #[test]
    fn freeze_policy_rejects() {
        let mut lr = LowRank::identity(4, 2, MemoryPolicy::Freeze);
        assert!(lr.push(&[1.0; 4], &[1.0; 4]));
        assert!(lr.push(&[2.0; 4], &[2.0; 4]));
        assert!(!lr.push(&[3.0; 4], &[3.0; 4]));
        assert_eq!(lr.rank(), 2);
        assert_eq!(lr.frozen_rejects, 1);
    }

    #[test]
    fn evict_policy_drops_oldest() {
        let mut lr = LowRank::identity(2, 2, MemoryPolicy::Evict);
        lr.push(&[1.0, 0.0], &[1.0, 0.0]);
        lr.push(&[0.0, 1.0], &[0.0, 1.0]);
        lr.push(&[2.0, 0.0], &[2.0, 0.0]);
        assert_eq!(lr.rank(), 2);
        // first factor (u=[1,0]) evicted: H e1 = e1 + 4 e1 = 5 e1
        let y = lr.apply_vec(&[1.0, 0.0]);
        assert_eq!(y, vec![5.0, 0.0]);
    }

    #[test]
    fn evict_keeps_newest_m_and_matches_dense() {
        // Property test for the ring-buffer eviction semantics: after
        // pushing `cap + extra` factors under Evict, exactly the newest
        // `cap` must survive (in order), and apply/apply_t must agree with a
        // dense reference built from those survivors alone.
        prop::check("lowrank-evict-newest", 20, |rng| {
            let n = 3 + rng.below(10);
            let cap = 1 + rng.below(6);
            let extra = 1 + rng.below(10);
            let total = cap + extra;
            let all: Vec<(Vec<f64>, Vec<f64>)> = (0..total)
                .map(|_| (rng.normal_vec(n), rng.normal_vec(n)))
                .collect();
            let mut lr = LowRank::identity(n, cap, MemoryPolicy::Evict);
            for (u, v) in &all {
                prop::ensure(lr.push(u, v), "evict push accepted")?;
            }
            prop::ensure(lr.rank() == cap, "rank == cap after overflow")?;
            // Survivors are the newest cap factors, oldest → newest.
            for (i, (u, v)) in lr.rows().enumerate() {
                let (wu, wv) = &all[total - cap + i];
                prop::ensure_close_vec(u, wu, 1e-15, "surviving u order")?;
                prop::ensure_close_vec(v, wv, 1e-15, "surviving v order")?;
            }
            // Dense reference over survivors only.
            let mut d = DMat::eye(n);
            for (u, v) in &all[total - cap..] {
                for i in 0..n {
                    for j in 0..n {
                        d[(i, j)] += u[i] * v[j];
                    }
                }
            }
            let x = rng.normal_vec(n);
            let mut want = vec![0.0; n];
            d.matvec(&x, &mut want);
            prop::ensure_close_vec(&lr.apply_vec(&x), &want, 1e-10, "apply after evict")?;
            d.matvec_t(&x, &mut want);
            prop::ensure_close_vec(&lr.apply_t_vec(&x), &want, 1e-10, "apply_t after evict")
        });
    }

    #[test]
    fn apply_multi_matches_columnwise() {
        prop::check("lowrank-multi", 10, |rng| {
            let n = 4 + rng.below(12);
            let k = 1 + rng.below(5);
            let mut lr = LowRank::identity(n, 8, MemoryPolicy::Evict);
            for _ in 0..rng.below(10) {
                lr.push(&rng.normal_vec(n), &rng.normal_vec(n));
            }
            let xs: Vec<f64> = (0..k * n).map(|_| rng.normal()).collect();
            let mut got = vec![0.0; k * n];
            lr.apply_multi(&xs, &mut got);
            for r in 0..k {
                let want = lr.apply_vec(&xs[r * n..(r + 1) * n]);
                prop::ensure_close_vec(&got[r * n..(r + 1) * n], &want, 1e-12, "multi col")?;
            }
            lr.apply_t_multi(&xs, &mut got);
            for r in 0..k {
                let want = lr.apply_t_vec(&xs[r * n..(r + 1) * n]);
                prop::ensure_close_vec(&got[r * n..(r + 1) * n], &want, 1e-12, "multi_t col")?;
            }
            Ok(())
        });
    }

    #[test]
    fn apply_multi_into_matches_apply_multi() {
        // The workspace-scratch multi form must be bit-identical to the
        // allocating form (same kernels, coefficients merely live in the
        // accumulator pool) — in both orientations and through the view.
        let mut rng = Rng::new(31);
        let n = 14;
        let k = 5;
        let mut lr = LowRank::identity(n, 6, MemoryPolicy::Evict);
        for _ in 0..7 {
            lr.push(&rng.normal_vec(n), &rng.normal_vec(n));
        }
        let xs: Vec<f64> = (0..k * n).map(|_| rng.normal()).collect();
        let mut want = vec![0.0; k * n];
        let mut got = vec![0.0; k * n];
        let mut ws = Workspace::new();
        lr.apply_multi(&xs, &mut want);
        lr.apply_multi_into(&xs, &mut got, &mut ws);
        assert_eq!(got, want);
        lr.apply_t_multi(&xs, &mut want);
        lr.apply_t_multi_into(&xs, &mut got, &mut ws);
        assert_eq!(got, want);
        // Transposed view swaps the orientations.
        lr.t().apply_multi_into(&xs, &mut got, &mut ws);
        assert_eq!(got, want);
    }

    #[test]
    fn transposed_view_and_into_transposed_agree() {
        let mut rng = Rng::new(5);
        let n = 9;
        let mut lr = LowRank::identity(n, 4, MemoryPolicy::Evict);
        for _ in 0..6 {
            lr.push(&rng.normal_vec(n), &rng.normal_vec(n));
        }
        let x = rng.normal_vec(n);
        let want_t = lr.apply_t_vec(&x);
        let want = lr.apply_vec(&x);
        // View: apply ↔ apply_t swapped, zero storage touched.
        let view = lr.t();
        assert_eq!(view.apply_vec(&x), want_t);
        assert_eq!(view.apply_t_vec(&x), want);
        assert_eq!(InvOp::dim(&view), n);
        // Owned O(1) transpose: same operator.
        let owned = lr.clone().into_transposed();
        assert_eq!(owned.apply_vec(&x), want_t);
        assert_eq!(owned.apply_t_vec(&x), want);
        // Double transpose round-trips.
        let back = owned.into_transposed();
        assert_eq!(back.apply_vec(&x), want);
    }

    #[test]
    fn with_max_mem_shrink_keeps_newest() {
        let mut lr = LowRank::identity(2, 4, MemoryPolicy::Evict);
        for k in 0..4 {
            lr.push(&[k as f64, 0.0], &[0.0, k as f64]);
        }
        let lr = lr.with_max_mem(2, MemoryPolicy::Freeze);
        assert_eq!(lr.rank(), 2);
        let rows: Vec<_> = lr.rows().map(|(u, _)| u[0]).collect();
        assert_eq!(rows, vec![2.0, 3.0]);
        assert_eq!(lr.policy(), MemoryPolicy::Freeze);
        assert_eq!(lr.max_mem(), 2);
    }

    #[test]
    fn pack_layout() {
        let mut lr = LowRank::identity(3, 4, MemoryPolicy::Evict);
        lr.push(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]);
        lr.push(&[7.0, 8.0, 9.0], &[10.0, 11.0, 12.0]);
        let (u, v) = lr.pack();
        assert_eq!(u, vec![1.0, 2.0, 3.0, 7.0, 8.0, 9.0]);
        assert_eq!(v, vec![4.0, 5.0, 6.0, 10.0, 11.0, 12.0]);
    }

    #[test]
    fn parallel_path_matches_serial() {
        // Big enough to cross PAR_MIN_ELEMS: results must be identical to
        // the dense-free serial reference (per-factor f64 dots are computed
        // identically regardless of chunking).
        let mut rng = Rng::new(23);
        let d = (PAR_MIN_ELEMS / 8) + 13; // rank 8 crosses the threshold, +13 un-aligns chunks
        let m = 9;
        let mut lr = LowRank::identity(d, m, MemoryPolicy::Freeze);
        for _ in 0..m {
            lr.push(&rng.normal_vec(d), &rng.normal_vec(d));
        }
        let x = rng.normal_vec(d);
        // Serial reference computed directly from the rows.
        let mut want = x.clone();
        for (u, v) in lr.rows() {
            let c = crate::linalg::vecops::dot(v, &x);
            for i in 0..d {
                want[i] += c * u[i];
            }
        }
        let got = lr.apply_vec(&x);
        for i in 0..d {
            assert!(
                (got[i] - want[i]).abs() <= 1e-12 * (1.0 + want[i].abs()),
                "idx {i}: {} vs {}",
                got[i],
                want[i]
            );
        }
    }

    #[test]
    fn f32_instantiation_tracks_f64() {
        // The same factor stream through LowRank<f32> and LowRank<f64> must
        // produce operators that agree to f32 tolerance (the precision-
        // parity integration test covers the full solver stack; this is the
        // in-module smoke check).
        let mut rng = Rng::new(41);
        let n = 24;
        let mut lr64 = LowRank::identity(n, 6, MemoryPolicy::Evict);
        let mut lr32: LowRank<f32> = LowRank::identity(n, 6, MemoryPolicy::Evict);
        for _ in 0..8 {
            let u = rng.normal_vec(n);
            let v = rng.normal_vec(n);
            let u32v: Vec<f32> = u.iter().map(|&a| a as f32).collect();
            let v32v: Vec<f32> = v.iter().map(|&a| a as f32).collect();
            lr64.push(&u, &v);
            lr32.push(&u32v, &v32v);
        }
        let x = rng.normal_vec(n);
        let x32: Vec<f32> = x.iter().map(|&a| a as f32).collect();
        let want = lr64.apply_vec(&x);
        let got = lr32.apply_vec(&x32);
        for i in 0..n {
            let w = want[i];
            assert!(
                (got[i] as f64 - w).abs() <= 1e-4 * (1.0 + w.abs()),
                "idx {i}: {} vs {}",
                got[i],
                w
            );
        }
    }
}
