//! Limited-memory low-rank representation `H = I + Σᵢ uᵢ vᵢᵀ`.
//!
//! Both Broyden's inverse form and the Sherman–Morrison-maintained inverse of
//! the Adjoint Broyden matrix live in this structure. Applying `H` or `Hᵀ`
//! costs `O(m·d)` — this is exactly why SHINE's backward pass is ~10× cheaper
//! than the iterative inversion (Fig. 3, Table E.2).

use crate::linalg::vecops::{axpy, dot};
use crate::qn::{InvOp, MemoryPolicy};

#[derive(Clone, Debug)]
pub struct LowRank {
    dim: usize,
    max_mem: usize,
    policy: MemoryPolicy,
    /// Rank-one factors; H x = x + Σ u_i (v_i · x).
    us: Vec<Vec<f64>>,
    vs: Vec<Vec<f64>>,
    /// Number of updates rejected because the buffer was frozen.
    pub frozen_rejects: usize,
}

impl LowRank {
    pub fn identity(dim: usize, max_mem: usize, policy: MemoryPolicy) -> Self {
        LowRank {
            dim,
            max_mem,
            policy,
            us: Vec::with_capacity(max_mem),
            vs: Vec::with_capacity(max_mem),
            frozen_rejects: 0,
        }
    }

    pub fn rank(&self) -> usize {
        self.us.len()
    }

    pub fn is_full(&self) -> bool {
        self.us.len() >= self.max_mem
    }

    /// Append a rank-one term `u vᵀ`. Returns false if frozen-full.
    pub fn push(&mut self, u: Vec<f64>, v: Vec<f64>) -> bool {
        debug_assert_eq!(u.len(), self.dim);
        debug_assert_eq!(v.len(), self.dim);
        if self.us.len() >= self.max_mem {
            match self.policy {
                MemoryPolicy::Freeze => {
                    self.frozen_rejects += 1;
                    return false;
                }
                MemoryPolicy::Evict => {
                    self.us.remove(0);
                    self.vs.remove(0);
                }
            }
        }
        self.us.push(u);
        self.vs.push(v);
        true
    }

    /// Direct access for warm-starting a backward solver from the forward
    /// estimate (the *refine* strategy).
    pub fn factors(&self) -> (&[Vec<f64>], &[Vec<f64>]) {
        (&self.us, &self.vs)
    }

    pub fn clear(&mut self) {
        self.us.clear();
        self.vs.clear();
        self.frozen_rejects = 0;
    }

    /// The transposed operator: (I + Σ u vᵀ)ᵀ = I + Σ v uᵀ. Used when the
    /// backward pass needs (J⁻¹)ᵀ ≈ Hᵀ as an *initial* estimate for the
    /// refine strategy's warm-started solver.
    pub fn transposed(&self) -> LowRank {
        LowRank {
            dim: self.dim,
            max_mem: self.max_mem,
            policy: self.policy,
            us: self.vs.clone(),
            vs: self.us.clone(),
            frozen_rejects: 0,
        }
    }

    /// Grow/shrink the memory budget (refine adds room for new updates on
    /// top of the forward estimate).
    pub fn with_max_mem(mut self, max_mem: usize, policy: MemoryPolicy) -> LowRank {
        self.max_mem = max_mem;
        self.policy = policy;
        while self.us.len() > max_mem {
            self.us.remove(0);
            self.vs.remove(0);
        }
        self
    }

    /// Pack factors into flat row-major (m, d) buffers — the layout the
    /// `lowrank_apply` Pallas artifact consumes.
    pub fn pack(&self) -> (Vec<f64>, Vec<f64>) {
        let mut u = Vec::with_capacity(self.rank() * self.dim);
        let mut v = Vec::with_capacity(self.rank() * self.dim);
        for i in 0..self.rank() {
            u.extend_from_slice(&self.us[i]);
            v.extend_from_slice(&self.vs[i]);
        }
        (u, v)
    }
}

impl InvOp for LowRank {
    fn dim(&self) -> usize {
        self.dim
    }

    fn apply(&self, x: &[f64], out: &mut [f64]) {
        out.copy_from_slice(x);
        for i in 0..self.us.len() {
            let c = dot(&self.vs[i], x);
            if c != 0.0 {
                axpy(c, &self.us[i], out);
            }
        }
    }

    fn apply_t(&self, x: &[f64], out: &mut [f64]) {
        out.copy_from_slice(x);
        for i in 0..self.us.len() {
            let c = dot(&self.us[i], x);
            if c != 0.0 {
                axpy(c, &self.vs[i], out);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dmat::DMat;
    use crate::util::prop;

    /// Dense materialization for oracle comparison.
    fn dense(lr: &LowRank) -> DMat {
        let n = lr.dim();
        let mut m = DMat::eye(n);
        let (us, vs) = lr.factors();
        for (u, v) in us.iter().zip(vs) {
            for i in 0..n {
                for j in 0..n {
                    m[(i, j)] += u[i] * v[j];
                }
            }
        }
        m
    }

    #[test]
    fn apply_matches_dense() {
        prop::check("lowrank-apply", 20, |rng| {
            let n = 3 + rng.below(20);
            let mut lr = LowRank::identity(n, 10, MemoryPolicy::Evict);
            for _ in 0..rng.below(8) {
                lr.push(rng.normal_vec(n), rng.normal_vec(n));
            }
            let d = dense(&lr);
            let x = rng.normal_vec(n);
            let mut want = vec![0.0; n];
            d.matvec(&x, &mut want);
            prop::ensure_close_vec(&lr.apply_vec(&x), &want, 1e-10, "apply")?;
            d.matvec_t(&x, &mut want);
            prop::ensure_close_vec(&lr.apply_t_vec(&x), &want, 1e-10, "apply_t")
        });
    }

    #[test]
    fn freeze_policy_rejects() {
        let mut lr = LowRank::identity(4, 2, MemoryPolicy::Freeze);
        assert!(lr.push(vec![1.0; 4], vec![1.0; 4]));
        assert!(lr.push(vec![2.0; 4], vec![2.0; 4]));
        assert!(!lr.push(vec![3.0; 4], vec![3.0; 4]));
        assert_eq!(lr.rank(), 2);
        assert_eq!(lr.frozen_rejects, 1);
    }

    #[test]
    fn evict_policy_drops_oldest() {
        let mut lr = LowRank::identity(2, 2, MemoryPolicy::Evict);
        lr.push(vec![1.0, 0.0], vec![1.0, 0.0]);
        lr.push(vec![0.0, 1.0], vec![0.0, 1.0]);
        lr.push(vec![2.0, 0.0], vec![2.0, 0.0]);
        assert_eq!(lr.rank(), 2);
        // first factor (u=[1,0]) evicted: H e1 = e1 + 4 e1 = 5 e1
        let y = lr.apply_vec(&[1.0, 0.0]);
        assert_eq!(y, vec![5.0, 0.0]);
    }

    #[test]
    fn pack_layout() {
        let mut lr = LowRank::identity(3, 4, MemoryPolicy::Evict);
        lr.push(vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]);
        lr.push(vec![7.0, 8.0, 9.0], vec![10.0, 11.0, 12.0]);
        let (u, v) = lr.pack();
        assert_eq!(u, vec![1.0, 2.0, 3.0, 7.0, 8.0, 9.0]);
        assert_eq!(v, vec![4.0, 5.0, 6.0, 10.0, 11.0, 12.0]);
    }
}
