//! Limited-memory low-rank representation `H = I + Σᵢ uᵢ vᵢᵀ`, generic over
//! the storage precision.
//!
//! Both Broyden's inverse form and the Sherman–Morrison-maintained inverse of
//! the Adjoint Broyden matrix live in this structure. Applying `H` or `Hᵀ`
//! costs `O(m·d)` — this is exactly why SHINE's backward pass is ~10× cheaper
//! than the iterative inversion (Fig. 3, Table E.2).
//!
//! The factors live in two flat row-major panels
//! ([`crate::qn::panel::FactorPanel`]): `H x` is a two-phase blocked
//! kernel — the coefficient sweep `c = V x` ([`panel_gemv`], f64
//! coefficients) followed by the accumulation sweep `out = x + Uᵀ c`
//! ([`panel_gemv_t`]) — parallelized over row/column chunks with
//! [`crate::util::threads::par_chunks_mut`] once the panel exceeds
//! [`PAR_MIN_ELEMS`]. Eviction is O(1) (ring rotation), and
//! [`LowRank::push_with`] fills the new factor's panel slots in place so
//! solver loops never allocate. At `E = f32` the sweeps move half the bytes
//! of the f64 instantiation while every dot still accumulates in f64 (the
//! [`Elem`] contract), and the half-width storages
//! ([`crate::linalg::vecops::Bf16`]/[`crate::linalg::vecops::F16`]) halve
//! them again.
//!
//! The structure carries **two storage parameters**, `LowRank<EU, EV>`
//! (`EV` defaults to `EU`, so the historical `LowRank<E>` spelling is
//! unchanged), and its [`InvOp`] implementation is **blanket over the
//! vector precision**: a `LowRank<Bf16, f32>` — the serving tier's mixed
//! layout, bf16 U factors next to f32 V factors — applies directly to f32
//! state vectors with no widening buffer, because every kernel operand
//! widens to f64 per element anyway.

use crate::linalg::vecops::{
    axpy, panel_gemv, panel_gemv_multi, panel_gemv_t, panel_gemv_t_multi, Elem,
};
use crate::qn::panel::FactorPanel;
use crate::qn::workspace::Workspace;
use crate::qn::{InvOp, MemoryPolicy};
use crate::util::threads;

/// Re-export of the kernel threading threshold (the constant moved to
/// [`crate::linalg::vecops`] when the multi-RHS kernels grew their own
/// thread paths; this alias keeps the historical `qn::low_rank` path alive).
pub use crate::linalg::vecops::PAR_MIN_ELEMS;

#[derive(Clone, Debug)]
pub struct LowRank<EU: Elem = f64, EV: Elem = EU> {
    panel: FactorPanel<EU, EV>,
    policy: MemoryPolicy,
    /// Number of updates rejected because the buffer was frozen.
    pub frozen_rejects: usize,
}

impl<EU: Elem, EV: Elem> LowRank<EU, EV> {
    pub fn identity(dim: usize, max_mem: usize, policy: MemoryPolicy) -> Self {
        LowRank {
            panel: FactorPanel::new(dim, max_mem),
            policy,
            frozen_rejects: 0,
        }
    }

    /// Dimension of the operator. Inherent (not just via [`InvOp`]) because
    /// the blanket `InvOp<X>` impl leaves `InvOp::dim(&lr)` without a unique
    /// `X` to infer — the inherent method needs none.
    pub fn dim(&self) -> usize {
        self.panel.dim()
    }

    pub fn rank(&self) -> usize {
        self.panel.len()
    }

    pub fn max_mem(&self) -> usize {
        self.panel.cap()
    }

    pub fn is_full(&self) -> bool {
        self.panel.is_full()
    }

    pub fn policy(&self) -> MemoryPolicy {
        self.policy
    }

    /// Append a rank-one term `u vᵀ`, filling the panel slots through
    /// `fill(u_slot, v_slot)` — no intermediate allocation. Under
    /// [`MemoryPolicy::Evict`] a full buffer drops its oldest factor in O(1);
    /// under [`MemoryPolicy::Freeze`] the update is rejected (returns false)
    /// and `fill` is never called.
    pub fn push_with(&mut self, fill: impl FnOnce(&mut [EU], &mut [EV])) -> bool {
        if self.panel.is_full() && self.policy == MemoryPolicy::Freeze {
            self.frozen_rejects += 1;
            return false;
        }
        let (_, us, vs) = self.panel.advance();
        fill(us, vs);
        true
    }

    /// Append a rank-one term `u vᵀ`. Returns false if frozen-full.
    pub fn push(&mut self, u: &[EU], v: &[EV]) -> bool {
        debug_assert_eq!(u.len(), self.panel.dim());
        debug_assert_eq!(v.len(), self.panel.dim());
        self.push_with(|us, vs| {
            us.copy_from_slice(u);
            vs.copy_from_slice(v);
        })
    }

    /// Factor pairs in logical (oldest → newest) order. Direct access for
    /// warm-starting a backward solver from the forward estimate (the
    /// *refine* strategy) and for dense test oracles.
    pub fn rows(&self) -> impl Iterator<Item = (&[EU], &[EV])> + '_ {
        self.panel.rows()
    }

    pub fn clear(&mut self) {
        self.panel.clear();
        self.frozen_rejects = 0;
    }

    /// Zero-copy view of the transposed operator
    /// `(I + Σ u vᵀ)ᵀ = I + Σ v uᵀ` — apply/apply_t swapped, no storage
    /// touched. Use when the backward pass only needs to *apply* `Hᵀ`.
    /// Available at any storage mix (both orientations of the kernels accept
    /// independent panel precisions).
    pub fn t(&self) -> TransposedView<'_, EU, EV> {
        TransposedView(self)
    }

    /// Grow/shrink the memory budget (refine adds room for new updates on
    /// top of the forward estimate). Keeps the newest factors on shrink;
    /// growing an unwrapped (Freeze-built) estimate is O(1).
    pub fn with_max_mem(mut self, max_mem: usize, policy: MemoryPolicy) -> LowRank<EU, EV> {
        self.panel.resize_cap(max_mem);
        self.policy = policy;
        self
    }

    /// Re-store the operator in the target precisions (widen to f64, narrow
    /// once per element — round-to-nearest-even for the half-width
    /// storages), preserving logical factor order, capacity and policy.
    /// This is how the serving tier demotes a freshly calibrated f32
    /// estimate into its reduced-precision panel layout. O(m·d); never on a
    /// hot path.
    pub fn convert<FU: Elem, FV: Elem>(&self) -> LowRank<FU, FV> {
        LowRank {
            panel: self.panel.convert(),
            policy: self.policy,
            frozen_rejects: self.frozen_rejects,
        }
    }

    /// Pack factors into flat row-major (m, d) buffers in logical order, in
    /// the panel's native storage precisions. For the PJRT artifact boundary
    /// use [`LowRank::pack_f32`], which performs the ABI conversion
    /// explicitly.
    pub fn pack(&self) -> (Vec<EU>, Vec<EV>) {
        let d = self.panel.dim();
        let mut u = Vec::with_capacity(self.rank() * d);
        let mut v = Vec::with_capacity(self.rank() * d);
        for (ur, vr) in self.rows() {
            u.extend_from_slice(ur);
            v.extend_from_slice(vr);
        }
        (u, v)
    }

    /// Pack factors into flat row-major (m, d) **f32** buffers in logical
    /// order — the layout and dtype the `lowrank_apply` Pallas artifact
    /// consumes (its manifest records `dtype: "f32"`; see
    /// `runtime/manifest.rs`). This is the sanctioned conversion point for
    /// feeding non-f32 panels to the AOT kernels: each element widens to f64
    /// and narrows to f32 exactly once, instead of the panel storage being
    /// silently assumed to match the artifact tensors.
    pub fn pack_f32(&self) -> (Vec<f32>, Vec<f32>) {
        let d = self.panel.dim();
        let mut u = Vec::with_capacity(self.rank() * d);
        let mut v = Vec::with_capacity(self.rank() * d);
        for (ur, vr) in self.rows() {
            u.extend(ur.iter().map(|x| x.to_f64() as f32));
            v.extend(vr.iter().map(|x| x.to_f64() as f32));
        }
        (u, v)
    }

    /// Two-phase blocked kernel shared by apply/apply_t: with
    /// `transpose == false` computes `out = x + Uᵀ (V x)`, with `true` the
    /// roles of the panels swap. `coeffs` must hold at least `rank()` f64
    /// slots (coefficients are reduction results and stay in accumulator
    /// precision). The two orientations dispatch to a helper generic over
    /// both panel precisions, since the coefficient panel and the
    /// accumulation panel have different storage types in a mixed layout.
    fn apply_impl<X: Elem>(&self, transpose: bool, x: &[X], out: &mut [X], coeffs: &mut [f64]) {
        out.copy_from_slice(x);
        let m = self.panel.len();
        if m == 0 {
            return;
        }
        let d = self.panel.dim();
        let coeffs = &mut coeffs[..m];
        if transpose {
            lr_apply_panels(self.panel.u_flat(), self.panel.v_flat(), m, d, x, out, coeffs);
        } else {
            lr_apply_panels(self.panel.v_flat(), self.panel.u_flat(), m, d, x, out, coeffs);
        }
    }

    /// Shared multi-RHS kernel: one coefficient sweep and one accumulation
    /// sweep over the panels serve all `k` right-hand sides (`xs`, `out` are
    /// row-major `k × d`); `coeffs` must hold at least `rank() · k` f64
    /// slots. The sweeps themselves shard across threads above
    /// [`PAR_MIN_ELEMS`] (see [`panel_gemv_multi`] / [`panel_gemv_t_multi`]).
    fn apply_multi_impl<X: Elem>(
        &self,
        transpose: bool,
        xs: &[X],
        out: &mut [X],
        coeffs: &mut [f64],
    ) {
        out.copy_from_slice(xs);
        let m = self.panel.len();
        if m == 0 {
            return;
        }
        let d = self.panel.dim();
        let k = xs.len() / d;
        debug_assert_eq!(xs.len(), k * d);
        let coeffs = &mut coeffs[..m * k];
        if transpose {
            panel_gemv_multi(self.panel.u_flat(), m, d, xs, k, coeffs);
            panel_gemv_t_multi(self.panel.v_flat(), m, d, coeffs, k, out);
        } else {
            panel_gemv_multi(self.panel.v_flat(), m, d, xs, k, coeffs);
            panel_gemv_t_multi(self.panel.u_flat(), m, d, coeffs, k, out);
        }
    }

    /// Right-hand-side count of a multi-RHS call (`xs.len() / dim`, robust
    /// to the empty-panel case the kernels early-return on).
    fn multi_k<X: Elem>(&self, xs: &[X]) -> usize {
        let d = self.panel.dim();
        if d == 0 {
            0
        } else {
            xs.len() / d
        }
    }
}

impl<E: Elem> LowRank<E, E> {
    /// Consume into the transposed operator by swapping the u/v panels —
    /// O(1), no copies. Use (after a clone when the forward estimate must be
    /// retained) when the transposed matrix seeds a solver that will push
    /// further updates, e.g. the refine strategy's warm-started backward
    /// Broyden. Homogeneous storage only: transposing a mixed layout would
    /// move the narrow panel onto the coefficient-sweep side, exactly the
    /// placement the layout exists to avoid (use [`LowRank::convert`] to
    /// change layouts explicitly).
    pub fn into_transposed(mut self) -> LowRank<E, E> {
        self.panel.swap_uv();
        self
    }
}

/// Single-RHS body of [`LowRank`]'s apply: one coefficient sweep over
/// `coef_panel`, one accumulation sweep over `acc_panel`, thread-parallel
/// above [`PAR_MIN_ELEMS`]. Generic over both panel storages and the vector
/// storage so every orientation of every layout shares this text.
fn lr_apply_panels<P: Elem, Q: Elem, X: Elem>(
    coef_panel: &[P],
    acc_panel: &[Q],
    m: usize,
    d: usize,
    x: &[X],
    out: &mut [X],
    coeffs: &mut [f64],
) {
    if m * d < PAR_MIN_ELEMS {
        panel_gemv(coef_panel, m, d, x, coeffs);
        panel_gemv_t(acc_panel, m, d, coeffs, out);
    } else {
        let workers = threads::ncpus().min(16);
        threads::par_chunks_mut(&mut coeffs[..], workers.min(m), |off, cc| {
            panel_gemv(&coef_panel[off * d..], cc.len(), d, x, cc);
        });
        let coeffs: &[f64] = coeffs;
        threads::par_chunks_mut(&mut out[..], workers, |off, oc| {
            for (i, &c) in coeffs.iter().enumerate() {
                if c != 0.0 {
                    axpy(c, &acc_panel[i * d + off..i * d + off + oc.len()], oc);
                }
            }
        });
    }
}

/// Blanket over the vector precision `X`: the kernels widen every operand
/// to f64 per element, so a panel stored at any `(EU, EV)` mix applies to
/// vectors of any `Elem` without intermediate buffers. The serving tier's
/// mixed layout (`LowRank<Bf16, f32>` acting on f32 batches) is one
/// instantiation of this impl.
impl<EU: Elem, EV: Elem, X: Elem> InvOp<X> for LowRank<EU, EV> {
    fn dim(&self) -> usize {
        self.panel.dim()
    }

    fn apply(&self, x: &[X], out: &mut [X]) {
        let mut coeffs = vec![0.0f64; self.panel.len()];
        self.apply_impl(false, x, out, &mut coeffs);
    }

    fn apply_t(&self, x: &[X], out: &mut [X]) {
        let mut coeffs = vec![0.0f64; self.panel.len()];
        self.apply_impl(true, x, out, &mut coeffs);
    }

    fn apply_into(&self, x: &[X], out: &mut [X], ws: &mut Workspace<X>) {
        // Power-of-two-quantized coefficient buffer: its size stays stable
        // while the rank grows, so the workspace stops reallocating after the
        // first few iterations of a solver run.
        let mut coeffs = ws.take_acc(self.panel.coeff_len());
        self.apply_impl(false, x, out, &mut coeffs);
        ws.give_acc(coeffs);
    }

    fn apply_t_into(&self, x: &[X], out: &mut [X], ws: &mut Workspace<X>) {
        let mut coeffs = ws.take_acc(self.panel.coeff_len());
        self.apply_impl(true, x, out, &mut coeffs);
        ws.give_acc(coeffs);
    }

    fn apply_multi(&self, xs: &[X], out: &mut [X]) {
        let mut coeffs = vec![0.0f64; self.panel.len() * self.multi_k(xs)];
        self.apply_multi_impl(false, xs, out, &mut coeffs);
    }

    fn apply_t_multi(&self, xs: &[X], out: &mut [X]) {
        let mut coeffs = vec![0.0f64; self.panel.len() * self.multi_k(xs)];
        self.apply_multi_impl(true, xs, out, &mut coeffs);
    }

    fn apply_multi_into(&self, xs: &[X], out: &mut [X], ws: &mut Workspace<X>) {
        // coeff_len-quantized block: stable take size while the rank grows,
        // so the serving loop's per-batch takes never reallocate.
        let mut coeffs = ws.take_acc(self.panel.coeff_len() * self.multi_k(xs));
        self.apply_multi_impl(false, xs, out, &mut coeffs);
        ws.give_acc(coeffs);
    }

    fn apply_t_multi_into(&self, xs: &[X], out: &mut [X], ws: &mut Workspace<X>) {
        let mut coeffs = ws.take_acc(self.panel.coeff_len() * self.multi_k(xs));
        self.apply_multi_impl(true, xs, out, &mut coeffs);
        ws.give_acc(coeffs);
    }
}

/// Zero-copy transposed view of a [`LowRank`]: `apply` and `apply_t` swap.
/// Created by [`LowRank::t`].
pub struct TransposedView<'a, EU: Elem = f64, EV: Elem = EU>(&'a LowRank<EU, EV>);

impl<EU: Elem, EV: Elem> TransposedView<'_, EU, EV> {
    /// Dimension of the viewed operator. Inherent for the same inference
    /// reason as [`LowRank::dim`].
    pub fn dim(&self) -> usize {
        self.0.dim()
    }
}

impl<EU: Elem, EV: Elem, X: Elem> InvOp<X> for TransposedView<'_, EU, EV> {
    fn dim(&self) -> usize {
        self.0.dim()
    }
    fn apply(&self, x: &[X], out: &mut [X]) {
        self.0.apply_t(x, out)
    }
    fn apply_t(&self, x: &[X], out: &mut [X]) {
        self.0.apply(x, out)
    }
    fn apply_into(&self, x: &[X], out: &mut [X], ws: &mut Workspace<X>) {
        self.0.apply_t_into(x, out, ws)
    }
    fn apply_t_into(&self, x: &[X], out: &mut [X], ws: &mut Workspace<X>) {
        self.0.apply_into(x, out, ws)
    }
    fn apply_multi(&self, xs: &[X], out: &mut [X]) {
        self.0.apply_t_multi(xs, out)
    }
    fn apply_t_multi(&self, xs: &[X], out: &mut [X]) {
        self.0.apply_multi(xs, out)
    }
    fn apply_multi_into(&self, xs: &[X], out: &mut [X], ws: &mut Workspace<X>) {
        self.0.apply_t_multi_into(xs, out, ws)
    }
    fn apply_t_multi_into(&self, xs: &[X], out: &mut [X], ws: &mut Workspace<X>) {
        self.0.apply_multi_into(xs, out, ws)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dmat::DMat;
    use crate::util::prop;
    use crate::util::rng::Rng;

    /// Dense materialization for oracle comparison.
    fn dense(lr: &LowRank) -> DMat {
        let n = lr.dim();
        let mut m = DMat::eye(n);
        for (u, v) in lr.rows() {
            for i in 0..n {
                for j in 0..n {
                    m[(i, j)] += u[i] * v[j];
                }
            }
        }
        m
    }

    #[test]
    fn apply_matches_dense() {
        prop::check("lowrank-apply", 20, |rng| {
            let n = 3 + rng.below(20);
            let mut lr = LowRank::identity(n, 10, MemoryPolicy::Evict);
            for _ in 0..rng.below(8) {
                lr.push(&rng.normal_vec(n), &rng.normal_vec(n));
            }
            let d = dense(&lr);
            let x = rng.normal_vec(n);
            let mut want = vec![0.0; n];
            d.matvec(&x, &mut want);
            prop::ensure_close_vec(&lr.apply_vec(&x), &want, 1e-10, "apply")?;
            d.matvec_t(&x, &mut want);
            prop::ensure_close_vec(&lr.apply_t_vec(&x), &want, 1e-10, "apply_t")
        });
    }

    #[test]
    fn apply_into_matches_apply() {
        let mut rng = Rng::new(17);
        let n = 12;
        let mut lr = LowRank::identity(n, 6, MemoryPolicy::Evict);
        for _ in 0..9 {
            lr.push(&rng.normal_vec(n), &rng.normal_vec(n));
        }
        let x = rng.normal_vec(n);
        let mut ws = Workspace::new();
        let mut got = vec![0.0; n];
        lr.apply_into(&x, &mut got, &mut ws);
        assert_eq!(got, lr.apply_vec(&x));
        lr.apply_t_into(&x, &mut got, &mut ws);
        assert_eq!(got, lr.apply_t_vec(&x));
    }

    #[test]
    fn freeze_policy_rejects() {
        let mut lr = LowRank::identity(4, 2, MemoryPolicy::Freeze);
        assert!(lr.push(&[1.0; 4], &[1.0; 4]));
        assert!(lr.push(&[2.0; 4], &[2.0; 4]));
        assert!(!lr.push(&[3.0; 4], &[3.0; 4]));
        assert_eq!(lr.rank(), 2);
        assert_eq!(lr.frozen_rejects, 1);
    }

    #[test]
    fn evict_policy_drops_oldest() {
        let mut lr = LowRank::identity(2, 2, MemoryPolicy::Evict);
        lr.push(&[1.0, 0.0], &[1.0, 0.0]);
        lr.push(&[0.0, 1.0], &[0.0, 1.0]);
        lr.push(&[2.0, 0.0], &[2.0, 0.0]);
        assert_eq!(lr.rank(), 2);
        // first factor (u=[1,0]) evicted: H e1 = e1 + 4 e1 = 5 e1
        let y = lr.apply_vec(&[1.0, 0.0]);
        assert_eq!(y, vec![5.0, 0.0]);
    }

    #[test]
    fn evict_keeps_newest_m_and_matches_dense() {
        // Property test for the ring-buffer eviction semantics: after
        // pushing `cap + extra` factors under Evict, exactly the newest
        // `cap` must survive (in order), and apply/apply_t must agree with a
        // dense reference built from those survivors alone.
        prop::check("lowrank-evict-newest", 20, |rng| {
            let n = 3 + rng.below(10);
            let cap = 1 + rng.below(6);
            let extra = 1 + rng.below(10);
            let total = cap + extra;
            let all: Vec<(Vec<f64>, Vec<f64>)> = (0..total)
                .map(|_| (rng.normal_vec(n), rng.normal_vec(n)))
                .collect();
            let mut lr = LowRank::identity(n, cap, MemoryPolicy::Evict);
            for (u, v) in &all {
                prop::ensure(lr.push(u, v), "evict push accepted")?;
            }
            prop::ensure(lr.rank() == cap, "rank == cap after overflow")?;
            // Survivors are the newest cap factors, oldest → newest.
            for (i, (u, v)) in lr.rows().enumerate() {
                let (wu, wv) = &all[total - cap + i];
                prop::ensure_close_vec(u, wu, 1e-15, "surviving u order")?;
                prop::ensure_close_vec(v, wv, 1e-15, "surviving v order")?;
            }
            // Dense reference over survivors only.
            let mut d = DMat::eye(n);
            for (u, v) in &all[total - cap..] {
                for i in 0..n {
                    for j in 0..n {
                        d[(i, j)] += u[i] * v[j];
                    }
                }
            }
            let x = rng.normal_vec(n);
            let mut want = vec![0.0; n];
            d.matvec(&x, &mut want);
            prop::ensure_close_vec(&lr.apply_vec(&x), &want, 1e-10, "apply after evict")?;
            d.matvec_t(&x, &mut want);
            prop::ensure_close_vec(&lr.apply_t_vec(&x), &want, 1e-10, "apply_t after evict")
        });
    }

    #[test]
    fn apply_multi_matches_columnwise() {
        prop::check("lowrank-multi", 10, |rng| {
            let n = 4 + rng.below(12);
            let k = 1 + rng.below(5);
            let mut lr = LowRank::identity(n, 8, MemoryPolicy::Evict);
            for _ in 0..rng.below(10) {
                lr.push(&rng.normal_vec(n), &rng.normal_vec(n));
            }
            let xs: Vec<f64> = (0..k * n).map(|_| rng.normal()).collect();
            let mut got = vec![0.0; k * n];
            lr.apply_multi(&xs, &mut got);
            for r in 0..k {
                let want = lr.apply_vec(&xs[r * n..(r + 1) * n]);
                prop::ensure_close_vec(&got[r * n..(r + 1) * n], &want, 1e-12, "multi col")?;
            }
            lr.apply_t_multi(&xs, &mut got);
            for r in 0..k {
                let want = lr.apply_t_vec(&xs[r * n..(r + 1) * n]);
                prop::ensure_close_vec(&got[r * n..(r + 1) * n], &want, 1e-12, "multi_t col")?;
            }
            Ok(())
        });
    }

    #[test]
    fn apply_multi_into_matches_apply_multi() {
        // The workspace-scratch multi form must be bit-identical to the
        // allocating form (same kernels, coefficients merely live in the
        // accumulator pool) — in both orientations and through the view.
        let mut rng = Rng::new(31);
        let n = 14;
        let k = 5;
        let mut lr = LowRank::identity(n, 6, MemoryPolicy::Evict);
        for _ in 0..7 {
            lr.push(&rng.normal_vec(n), &rng.normal_vec(n));
        }
        let xs: Vec<f64> = (0..k * n).map(|_| rng.normal()).collect();
        let mut want = vec![0.0; k * n];
        let mut got = vec![0.0; k * n];
        let mut ws = Workspace::new();
        lr.apply_multi(&xs, &mut want);
        lr.apply_multi_into(&xs, &mut got, &mut ws);
        assert_eq!(got, want);
        lr.apply_t_multi(&xs, &mut want);
        lr.apply_t_multi_into(&xs, &mut got, &mut ws);
        assert_eq!(got, want);
        // Transposed view swaps the orientations.
        lr.t().apply_multi_into(&xs, &mut got, &mut ws);
        assert_eq!(got, want);
    }

    #[test]
    fn transposed_view_and_into_transposed_agree() {
        let mut rng = Rng::new(5);
        let n = 9;
        let mut lr = LowRank::identity(n, 4, MemoryPolicy::Evict);
        for _ in 0..6 {
            lr.push(&rng.normal_vec(n), &rng.normal_vec(n));
        }
        let x = rng.normal_vec(n);
        let want_t = lr.apply_t_vec(&x);
        let want = lr.apply_vec(&x);
        // View: apply ↔ apply_t swapped, zero storage touched.
        let view = lr.t();
        assert_eq!(view.apply_vec(&x), want_t);
        assert_eq!(view.apply_t_vec(&x), want);
        assert_eq!(view.dim(), n);
        // Owned O(1) transpose: same operator.
        let owned = lr.clone().into_transposed();
        assert_eq!(owned.apply_vec(&x), want_t);
        assert_eq!(owned.apply_t_vec(&x), want);
        // Double transpose round-trips.
        let back = owned.into_transposed();
        assert_eq!(back.apply_vec(&x), want);
    }

    #[test]
    fn with_max_mem_shrink_keeps_newest() {
        let mut lr = LowRank::identity(2, 4, MemoryPolicy::Evict);
        for k in 0..4 {
            lr.push(&[k as f64, 0.0], &[0.0, k as f64]);
        }
        let lr = lr.with_max_mem(2, MemoryPolicy::Freeze);
        assert_eq!(lr.rank(), 2);
        let rows: Vec<_> = lr.rows().map(|(u, _)| u[0]).collect();
        assert_eq!(rows, vec![2.0, 3.0]);
        assert_eq!(lr.policy(), MemoryPolicy::Freeze);
        assert_eq!(lr.max_mem(), 2);
    }

    #[test]
    fn pack_layout() {
        let mut lr = LowRank::identity(3, 4, MemoryPolicy::Evict);
        lr.push(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]);
        lr.push(&[7.0, 8.0, 9.0], &[10.0, 11.0, 12.0]);
        let (u, v) = lr.pack();
        assert_eq!(u, vec![1.0, 2.0, 3.0, 7.0, 8.0, 9.0]);
        assert_eq!(v, vec![4.0, 5.0, 6.0, 10.0, 11.0, 12.0]);
    }

    #[test]
    fn parallel_path_matches_serial() {
        // Big enough to cross PAR_MIN_ELEMS: results must be identical to
        // the dense-free serial reference (per-factor f64 dots are computed
        // identically regardless of chunking).
        let mut rng = Rng::new(23);
        let d = (PAR_MIN_ELEMS / 8) + 13; // rank 8 crosses the threshold, +13 un-aligns chunks
        let m = 9;
        let mut lr = LowRank::identity(d, m, MemoryPolicy::Freeze);
        for _ in 0..m {
            lr.push(&rng.normal_vec(d), &rng.normal_vec(d));
        }
        let x = rng.normal_vec(d);
        // Serial reference computed directly from the rows.
        let mut want = x.clone();
        for (u, v) in lr.rows() {
            let c = crate::linalg::vecops::dot(v, &x);
            for i in 0..d {
                want[i] += c * u[i];
            }
        }
        let got = lr.apply_vec(&x);
        for i in 0..d {
            assert!(
                (got[i] - want[i]).abs() <= 1e-12 * (1.0 + want[i].abs()),
                "idx {i}: {} vs {}",
                got[i],
                want[i]
            );
        }
    }

    #[test]
    fn f32_instantiation_tracks_f64() {
        // The same factor stream through LowRank<f32> and LowRank<f64> must
        // produce operators that agree to f32 tolerance (the precision-
        // parity integration test covers the full solver stack; this is the
        // in-module smoke check).
        let mut rng = Rng::new(41);
        let n = 24;
        let mut lr64 = LowRank::identity(n, 6, MemoryPolicy::Evict);
        let mut lr32: LowRank<f32> = LowRank::identity(n, 6, MemoryPolicy::Evict);
        for _ in 0..8 {
            let u = rng.normal_vec(n);
            let v = rng.normal_vec(n);
            let u32v: Vec<f32> = u.iter().map(|&a| a as f32).collect();
            let v32v: Vec<f32> = v.iter().map(|&a| a as f32).collect();
            lr64.push(&u, &v);
            lr32.push(&u32v, &v32v);
        }
        let x = rng.normal_vec(n);
        let x32: Vec<f32> = x.iter().map(|&a| a as f32).collect();
        let want = lr64.apply_vec(&x);
        let got = lr32.apply_vec(&x32);
        for i in 0..n {
            let w = want[i];
            assert!(
                (got[i] as f64 - w).abs() <= 1e-4 * (1.0 + w.abs()),
                "idx {i}: {} vs {}",
                got[i],
                w
            );
        }
    }

    #[test]
    fn mixed_layout_applies_to_f32_and_tracks_reference() {
        // LowRank<Bf16, f32> — the serving tier's mixed layout — applies
        // directly to f32 vectors through the blanket InvOp. Reference: the
        // same (already-narrowed) factors widened to f64, so the comparison
        // isolates kernel arithmetic from storage rounding and can use a
        // tight tolerance.
        use crate::linalg::vecops::Bf16;
        let mut rng = Rng::new(77);
        let n = 32;
        let mut mixed: LowRank<Bf16, f32> = LowRank::identity(n, 6, MemoryPolicy::Evict);
        let mut wide = LowRank::identity(n, 6, MemoryPolicy::Evict);
        for _ in 0..8 {
            let u = rng.normal_vec(n);
            let v = rng.normal_vec(n);
            let u16v: Vec<Bf16> = u.iter().map(|&a| Bf16::from_f64(a)).collect();
            let v32v: Vec<f32> = v.iter().map(|&a| a as f32).collect();
            wide.push(
                &u16v.iter().map(|b| b.to_f64()).collect::<Vec<f64>>(),
                &v32v.iter().map(|&b| b as f64).collect::<Vec<f64>>(),
            );
            mixed.push(&u16v, &v32v);
        }
        let x = rng.normal_vec(n);
        let x32: Vec<f32> = x.iter().map(|&a| a as f32).collect();
        let xw: Vec<f64> = x32.iter().map(|&a| a as f64).collect();
        for transpose in [false, true] {
            let want = if transpose {
                wide.apply_t_vec(&xw)
            } else {
                wide.apply_vec(&xw)
            };
            let got = if transpose {
                mixed.apply_t_vec(&x32)
            } else {
                mixed.apply_vec(&x32)
            };
            for i in 0..n {
                let w = want[i];
                assert!(
                    (got[i] as f64 - w).abs() <= 1e-4 * (1.0 + w.abs()),
                    "transpose={transpose} idx {i}: {} vs {}",
                    got[i],
                    w
                );
            }
        }
        // The zero-copy transposed view works at the mixed layout too.
        let view = mixed.t();
        assert_eq!(view.dim(), n);
        assert_eq!(view.apply_vec(&x32), mixed.apply_t_vec(&x32));
    }

    #[test]
    fn convert_round_trips_and_pack_f32_matches() {
        use crate::linalg::vecops::Bf16;
        let mut rng = Rng::new(101);
        let n = 12;
        let mut lr32: LowRank<f32> = LowRank::identity(n, 4, MemoryPolicy::Evict);
        for _ in 0..5 {
            let u: Vec<f32> = rng.normal_vec(n).iter().map(|&a| a as f32).collect();
            let v: Vec<f32> = rng.normal_vec(n).iter().map(|&a| a as f32).collect();
            lr32.push(&u, &v);
        }
        // Demote to the mixed layout, then widen back: the f32 V panel must
        // round-trip exactly, the bf16 U panel re-narrows to identical bits.
        let mixed: LowRank<Bf16, f32> = lr32.convert();
        assert_eq!(mixed.rank(), lr32.rank());
        assert_eq!(mixed.max_mem(), lr32.max_mem());
        assert_eq!(mixed.policy(), lr32.policy());
        for ((u32r, v32r), (umx, vmx)) in lr32.rows().zip(mixed.rows()) {
            for (a, b) in u32r.iter().zip(umx.iter()) {
                assert_eq!(Bf16::from_f64(*a as f64).to_bits(), b.to_bits());
            }
            assert_eq!(v32r, vmx);
        }
        let again: LowRank<Bf16, f32> = mixed.convert::<f32, f32>().convert();
        for ((a_u, a_v), (b_u, b_v)) in mixed.rows().zip(again.rows()) {
            assert!(a_u.iter().zip(b_u).all(|(x, y)| x.to_bits() == y.to_bits()));
            assert_eq!(a_v, b_v);
        }
        // pack_f32 on the mixed layout = widened-u, unchanged-v flat panels.
        let (pu, pv) = mixed.pack_f32();
        let (nu, nv) = mixed.pack();
        assert_eq!(pu.len(), mixed.rank() * n);
        assert!(pu
            .iter()
            .zip(nu.iter())
            .all(|(a, b)| *a as f64 == b.to_f64()));
        assert_eq!(pv, nv);
    }
}
