//! Quasi-Newton substrate — the heart of SHINE.
//!
//! The paper's key observation (§2.1): the qN matrices `B_n` built by the
//! *forward* solver are low-rank perturbations of the identity whose inverse
//! can be applied in O(m·d) by Sherman–Morrison, so the backward pass can
//! reuse them (`p_θ = ∇L(z*) B⁻¹ ∂g/∂θ`, eq. 4) instead of running an
//! iterative inversion of the true Jacobian.
//!
//! Three families are implemented, matching Algorithm 1 and Appendix A:
//! * [`broyden`] — Broyden's "good" method in inverse form (the DEQ forward
//!   solver of Bai et al. 2019/2020),
//! * [`lbfgs`] — (L)BFGS on inverse-Hessian form with the paper's **OPA**
//!   extra updates (Algorithm LBFGS, Theorem 3),
//! * [`adjoint_broyden`] — Adjoint Broyden à la Schlenkrich et al. with the
//!   OPA secant (7)/(8) (Theorem 4).
//!
//! # Storage and execution architecture
//!
//! The whole family stack is **precision-generic** over the storage scalar
//! [`crate::linalg::vecops::Elem`] (`f64`, `f32`, and the half-width
//! [`crate::linalg::vecops::Bf16`]/[`crate::linalg::vecops::F16`]), with
//! defaults of `f64` everywhere so the bi-level/HOAG experiments read
//! exactly as before. The precision contract is *store narrow, accumulate
//! wide*: panels, iterates and cotangents live in `E`, while every
//! reduction (dot products, norms, Sherman–Morrison denominators,
//! `ρ = 1/yᵀs`, two-loop α/β) is carried in `f64` — see
//! [`crate::linalg::vecops`]. The DEQ path instantiates the stack at
//! `E = f32` end-to-end (the fixed point is f32 at the artifact boundary
//! anyway), halving the panel memory traffic that dominates the backward
//! cost at MDEQ scale; the bi-level path stays at `E = f64`. All
//! instantiations coexist — `LowRank<f32>` and `LowRank<f64>` are
//! independent monomorphizations of the same kernels, proven equivalent to
//! f32 tolerance by `rust/tests/precision_parity.rs`, with the half-width
//! instantiations covered at looser (documented) tolerances.
//!
//! [`LowRank`] additionally takes a **second storage parameter**
//! (`LowRank<EU, EV>`, `EV` defaulting to `EU`) so the serving tier can run
//! the *mixed layout* — bf16 U factors with f32 V factors — and its
//! [`InvOp`] impl is blanket over the vector precision, so reduced-precision
//! panels apply directly to f32 batches. Solvers that *build* estimates
//! (the three qN families) stay homogeneous in `E`; reduced precision is a
//! storage demotion applied after calibration (`LowRank::convert`), guarded
//! at serve time by the §3 fallback check (see
//! `docs/adr/003-reduced-precision-panels.md`).
//!
//! All three families store their rank-one factors in a
//! [`panel::FactorPanel<E>`]: two flat row-major `m × d` panels behind a
//! ring buffer, so applying `H`/`Hᵀ` is a pair of contiguous panel sweeps
//! (`panel_gemv` → `panel_gemv_t` in [`crate::linalg::vecops`], thread-
//! parallel above a size threshold) and eviction is an O(1) ring rotation.
//! Updates write into panel slots in place, and every scratch vector a
//! solver iteration needs comes from a [`workspace::Workspace<E>`] arena —
//! storage scratch in `E`, reduction scratch in `f64` via
//! [`workspace::Workspace::take_acc`]. After warm-up, the hot loops of
//! `broyden_solve` and friends perform zero heap allocations in **both**
//! precisions (enforced by the counting-allocator test in
//! `rust/tests/qn_alloc.rs`).
//!
//! For serving many cotangents at once, [`InvOp`] also exposes multi-RHS
//! application (`apply_multi`/`apply_t_multi`): a whole batch of SHINE
//! backward directions is computed in one panel sweep, sharded across
//! threads for large batches (`panel_gemv_multi`/`panel_gemv_t_multi`).
//! The workspace forms (`apply_multi_into`/`apply_t_multi_into`) draw the
//! coefficient block from a [`Workspace`], which is what lets the batched
//! serving engine ([`crate::serve`]) answer every cotangent of a batch with
//! one sweep and zero allocations per batch.

pub mod adjoint_broyden;
pub mod broyden;
pub mod lbfgs;
pub mod low_rank;
pub mod panel;
pub mod workspace;

pub use adjoint_broyden::AdjointBroyden;
pub use broyden::BroydenInverse;
pub use lbfgs::LbfgsInverse;
pub use low_rank::LowRank;
pub use panel::FactorPanel;
pub use workspace::Workspace;

use crate::linalg::vecops::Elem;

/// An estimate of the *inverse* Jacobian/Hessian that can be applied to
/// vectors from both sides, generic over the storage precision `E`
/// (defaulting to `f64`, so `dyn InvOp` keeps meaning the double-precision
/// operator). This is what the forward pass hands to the backward pass
/// under SHINE.
pub trait InvOp<E: Elem = f64> {
    /// dimension d of the underlying operator
    fn dim(&self) -> usize;
    /// out = H x   (approximates J⁻¹ x)
    fn apply(&self, x: &[E], out: &mut [E]);
    /// out = Hᵀ x  (approximates J⁻ᵀ x; the direction eq. (3) needs)
    fn apply_t(&self, x: &[E], out: &mut [E]);

    /// out = H x, drawing every scratch buffer from `ws` — allocation-free
    /// after the workspace has warmed up. Implementations that need no
    /// scratch fall through to [`InvOp::apply`].
    fn apply_into(&self, x: &[E], out: &mut [E], _ws: &mut Workspace<E>) {
        self.apply(x, out);
    }

    /// out = Hᵀ x with workspace-provided scratch (see [`InvOp::apply_into`]).
    fn apply_t_into(&self, x: &[E], out: &mut [E], _ws: &mut Workspace<E>) {
        self.apply_t(x, out);
    }

    /// Apply `H` to `k = xs.len() / dim()` right-hand sides stored row-major
    /// (`k × d`) into `out` (same layout). The default loops column by
    /// column; panel-backed implementations override this with a single
    /// blocked sweep so a batch of SHINE cotangents costs one pass over the
    /// factors.
    fn apply_multi(&self, xs: &[E], out: &mut [E]) {
        let d = self.dim();
        debug_assert_eq!(xs.len() % d, 0);
        debug_assert_eq!(xs.len(), out.len());
        for (x, o) in xs.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
            self.apply(x, o);
        }
    }

    /// Multi-RHS `Hᵀ` application (see [`InvOp::apply_multi`]).
    fn apply_t_multi(&self, xs: &[E], out: &mut [E]) {
        let d = self.dim();
        debug_assert_eq!(xs.len() % d, 0);
        debug_assert_eq!(xs.len(), out.len());
        for (x, o) in xs.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
            self.apply_t(x, o);
        }
    }

    /// Multi-RHS `H` application drawing every scratch buffer from `ws` —
    /// allocation-free after warm-up for panel-backed implementations.
    /// Implementations with no scratch fall through to
    /// [`InvOp::apply_multi`].
    fn apply_multi_into(&self, xs: &[E], out: &mut [E], _ws: &mut Workspace<E>) {
        self.apply_multi(xs, out);
    }

    /// Multi-RHS `Hᵀ` application with workspace-provided scratch (see
    /// [`InvOp::apply_multi_into`]). This is the serving-path backward: a
    /// whole batch of SHINE cotangents answered by one call — a single
    /// panel sweep with zero heap allocations once the workspace is warm.
    fn apply_t_multi_into(&self, xs: &[E], out: &mut [E], _ws: &mut Workspace<E>) {
        self.apply_t_multi(xs, out);
    }

    /// Convenience allocating forms.
    fn apply_vec(&self, x: &[E]) -> Vec<E> {
        let mut out = vec![E::ZERO; x.len()];
        self.apply(x, &mut out);
        out
    }
    fn apply_t_vec(&self, x: &[E]) -> Vec<E> {
        let mut out = vec![E::ZERO; x.len()];
        self.apply_t(x, &mut out);
        out
    }
}

/// The identity operator — the Jacobian-Free method's "inverse estimate"
/// (Fung et al. 2021): J⁻¹ ≈ I. Implements [`InvOp`] at every storage
/// precision.
pub struct IdentityOp(pub usize);

impl<E: Elem> InvOp<E> for IdentityOp {
    fn dim(&self) -> usize {
        self.0
    }
    fn apply(&self, x: &[E], out: &mut [E]) {
        out.copy_from_slice(x);
    }
    fn apply_t(&self, x: &[E], out: &mut [E]) {
        out.copy_from_slice(x);
    }
}

/// Memory policy when the update buffer is full.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemoryPolicy {
    /// Stop accepting updates (the MDEQ limited-memory Broyden behaviour).
    Freeze,
    /// Evict the oldest update (the classical L-BFGS behaviour).
    Evict,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_op_is_identity() {
        let id = IdentityOp(3);
        let x = [1.0f64, -2.0, 3.0];
        assert_eq!(id.apply_vec(&x), x.to_vec());
        assert_eq!(id.apply_t_vec(&x), x.to_vec());
        assert_eq!(InvOp::<f64>::dim(&id), 3);
        // The same operator serves f32 storage.
        let x32 = [1.0f32, -2.0, 3.0];
        assert_eq!(id.apply_vec(&x32), x32.to_vec());
    }

    #[test]
    fn default_multi_loops_columns() {
        let id = IdentityOp(2);
        let xs = [1.0f64, 2.0, 3.0, 4.0];
        let mut out = [0.0f64; 4];
        id.apply_multi(&xs, &mut out);
        assert_eq!(out, xs);
        id.apply_t_multi(&xs, &mut out);
        assert_eq!(out, xs);
    }

    #[test]
    fn default_into_falls_through() {
        let id = IdentityOp(3);
        let mut ws = Workspace::new();
        let mut out = [0.0f64; 3];
        id.apply_into(&[1.0, 2.0, 3.0], &mut out, &mut ws);
        assert_eq!(out, [1.0, 2.0, 3.0]);
    }
}
