//! Quasi-Newton substrate — the heart of SHINE.
//!
//! The paper's key observation (§2.1): the qN matrices `B_n` built by the
//! *forward* solver are low-rank perturbations of the identity whose inverse
//! can be applied in O(m·d) by Sherman–Morrison, so the backward pass can
//! reuse them (`p_θ = ∇L(z*) B⁻¹ ∂g/∂θ`, eq. 4) instead of running an
//! iterative inversion of the true Jacobian.
//!
//! Three families are implemented, matching Algorithm 1 and Appendix A:
//! * [`broyden`] — Broyden's "good" method in inverse form (the DEQ forward
//!   solver of Bai et al. 2019/2020),
//! * [`lbfgs`] — (L)BFGS on inverse-Hessian form with the paper's **OPA**
//!   extra updates (Algorithm LBFGS, Theorem 3),
//! * [`adjoint_broyden`] — Adjoint Broyden à la Schlenkrich et al. with the
//!   OPA secant (7)/(8) (Theorem 4).

pub mod adjoint_broyden;
pub mod broyden;
pub mod lbfgs;
pub mod low_rank;

pub use adjoint_broyden::AdjointBroyden;
pub use broyden::BroydenInverse;
pub use lbfgs::LbfgsInverse;
pub use low_rank::LowRank;

/// An estimate of the *inverse* Jacobian/Hessian that can be applied to
/// vectors from both sides. This is what the forward pass hands to the
/// backward pass under SHINE.
pub trait InvOp {
    /// dimension d of the underlying operator
    fn dim(&self) -> usize;
    /// out = H x   (approximates J⁻¹ x)
    fn apply(&self, x: &[f64], out: &mut [f64]);
    /// out = Hᵀ x  (approximates J⁻ᵀ x; the direction eq. (3) needs)
    fn apply_t(&self, x: &[f64], out: &mut [f64]);

    /// Convenience allocating forms.
    fn apply_vec(&self, x: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; x.len()];
        self.apply(x, &mut out);
        out
    }
    fn apply_t_vec(&self, x: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; x.len()];
        self.apply_t(x, &mut out);
        out
    }
}

/// The identity operator — the Jacobian-Free method's "inverse estimate"
/// (Fung et al. 2021): J⁻¹ ≈ I.
pub struct IdentityOp(pub usize);

impl InvOp for IdentityOp {
    fn dim(&self) -> usize {
        self.0
    }
    fn apply(&self, x: &[f64], out: &mut [f64]) {
        out.copy_from_slice(x);
    }
    fn apply_t(&self, x: &[f64], out: &mut [f64]) {
        out.copy_from_slice(x);
    }
}

/// Memory policy when the update buffer is full.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemoryPolicy {
    /// Stop accepting updates (the MDEQ limited-memory Broyden behaviour).
    Freeze,
    /// Evict the oldest update (the classical L-BFGS behaviour).
    Evict,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_op_is_identity() {
        let id = IdentityOp(3);
        let x = [1.0, -2.0, 3.0];
        assert_eq!(id.apply_vec(&x), x.to_vec());
        assert_eq!(id.apply_t_vec(&x), x.to_vec());
        assert_eq!(id.dim(), 3);
    }
}
