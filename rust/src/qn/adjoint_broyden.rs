//! Adjoint Broyden method (Schlenkrich, Griewank & Walther 2010) with the
//! paper's OPA secant condition (§2.3, eqs. (7)–(8); Theorem 4).
//!
//! The direct update for a direction σ is
//!
//! ```text
//! B_{n+1} = B_n + σ (σᵀ(J(z_{n+1}) − B_n)) / ‖σ‖²
//! ```
//!
//! which enforces the *adjoint* secant condition  σᵀ B_{n+1} = σᵀ J(z_{n+1}).
//! OPA chooses σ = v_n with v_nᵀ = ∇_z L(z_n) B_n⁻¹ — the exact direction in
//! which the hypergradient formula (3) applies the inverse Jacobian from the
//! left. The row σᵀJ is obtained with one VJP (auto-diff in the DEQ case,
//! an analytic Hessian-vector product in the bi-level case) — the extra cost
//! the paper notes for this method.
//!
//! We maintain **both** the direct factors (B = I + Σ aᵢbᵢᵀ, in a
//! [`FactorPanel`]) and the inverse (H = B⁻¹, via Sherman–Morrison in a
//! [`LowRank`]) so SHINE can apply H and Hᵀ in O(m·d). Generic over the
//! storage precision [`Elem`] like the rest of the family stack (f32 panels
//! on the DEQ path, f64 default elsewhere; ‖σ‖², the Sherman–Morrison
//! denominator and the row coefficients are always f64). The OPA update path
//! ([`AdjointBroyden::update_ws`]) draws all of its temporaries from a
//! [`Workspace`] and writes new factors straight into panel slots —
//! allocation-free once warm.

use crate::linalg::vecops::{dot, negate, nrm2, panel_gemv, panel_gemv_t, Elem};
use crate::qn::low_rank::LowRank;
use crate::qn::panel::FactorPanel;
use crate::qn::workspace::Workspace;
use crate::qn::{InvOp, MemoryPolicy};

#[derive(Clone, Debug)]
pub struct AdjointBroyden<E: Elem = f64> {
    dim: usize,
    /// Direct low-rank factors: B = I + Σ a_i b_iᵀ (u-rows = a, v-rows = b).
    direct: FactorPanel<E>,
    /// Inverse estimate maintained by Sherman–Morrison.
    h: LowRank<E>,
    pub denom_eps: f64,
    pub skipped: usize,
}

impl<E: Elem> AdjointBroyden<E> {
    pub fn new(dim: usize, max_mem: usize, policy: MemoryPolicy) -> Self {
        AdjointBroyden {
            dim,
            direct: FactorPanel::new(dim, max_mem),
            h: LowRank::identity(dim, max_mem, policy),
            denom_eps: 1e-10,
            skipped: 0,
        }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn rank(&self) -> usize {
        self.direct.len()
    }

    /// out = σᵀ B_n  (row-vector result stored as a plain vector).
    pub fn left_apply_direct(&self, sigma: &[E], out: &mut [E]) {
        let mut coeffs = vec![0.0f64; self.direct.len()];
        self.left_apply_direct_with(sigma, out, &mut coeffs);
    }

    /// Workspace-scratch variant of [`AdjointBroyden::left_apply_direct`].
    pub fn left_apply_direct_into(&self, sigma: &[E], out: &mut [E], ws: &mut Workspace<E>) {
        let mut coeffs = ws.take_acc(self.direct.coeff_len());
        self.left_apply_direct_with(sigma, out, &mut coeffs);
        ws.give_acc(coeffs);
    }

    /// σᵀ B = σᵀ + Σᵢ (aᵢ·σ) bᵢᵀ — the same two-phase panel sweep as the
    /// low-rank apply, over the direct factors (f64 coefficients).
    fn left_apply_direct_with(&self, sigma: &[E], out: &mut [E], coeffs: &mut [f64]) {
        out.copy_from_slice(sigma);
        let m = self.direct.len();
        if m == 0 {
            return;
        }
        let coeffs = &mut coeffs[..m];
        panel_gemv(self.direct.u_flat(), m, self.dim, sigma, coeffs);
        panel_gemv_t(self.direct.v_flat(), m, self.dim, coeffs, out);
    }

    /// Update with direction σ and the row `sigma_j = σᵀ J(z_{n+1})`
    /// (computed by the caller through a VJP), drawing scratch from `ws`.
    /// Returns false if skipped. Allocation-free once `ws` is warm.
    pub fn update_ws(&mut self, sigma: &[E], sigma_j: &[E], ws: &mut Workspace<E>) -> bool {
        let ns2 = dot(sigma, sigma);
        // Scale-aware degenerate-σ guard: a = σ/‖σ‖² has ‖a‖ = 1/‖σ‖, so the
        // update is only representable when that magnitude fits the storage
        // precision — for f32 a merely-tiny (not zero) σ would narrow to inf
        // and poison the panels. `from_f64` is identity for f64, where the
        // second test can only fire after the 1e-300 floor already has.
        if ns2 <= 1e-300 || !E::from_f64(1.0 / ns2.sqrt()).to_f64().is_finite() {
            self.skipped += 1;
            return false;
        }
        if self.direct.is_full() {
            // Freeze (mirror of the Broyden forward behaviour): both the
            // direct and inverse stacks stop growing together.
            self.skipped += 1;
            return false;
        }
        let d = self.dim;
        // c = σᵀJ − σᵀB  (the row correction)
        let mut c = ws.take(d);
        self.left_apply_direct_into(sigma, &mut c, ws);
        for i in 0..d {
            c[i] = E::from_f64(sigma_j[i].to_f64() - c[i].to_f64());
        }
        // a = σ / ‖σ‖²
        let mut a = ws.take(d);
        for i in 0..d {
            a[i] = E::from_f64(sigma[i].to_f64() / ns2);
        }
        // Sherman–Morrison for the inverse: denom = 1 + cᵀ H a.
        let mut ha = ws.take(d);
        self.h.apply_into(&a, &mut ha, ws);
        let denom = 1.0 + dot(&c, &ha);
        if denom.abs() <= self.denom_eps * (1.0 + nrm2(&c) * nrm2(&ha)) {
            self.skipped += 1;
            ws.give(ha);
            ws.give(a);
            ws.give(c);
            return false;
        }
        let mut cth = ws.take(d);
        self.h.apply_t_into(&c, &mut cth, ws); // (cᵀ H)ᵀ = Hᵀ c
        self.h.push_with(|u_slot, v_slot| {
            for i in 0..d {
                u_slot[i] = E::from_f64(-ha[i].to_f64() / denom);
            }
            v_slot.copy_from_slice(&cth);
        });
        let (_, a_slot, b_slot) = self.direct.advance();
        a_slot.copy_from_slice(&a);
        b_slot.copy_from_slice(&c);
        ws.give(cth);
        ws.give(ha);
        ws.give(a);
        ws.give(c);
        true
    }

    /// Allocating convenience wrapper over [`AdjointBroyden::update_ws`].
    pub fn update(&mut self, sigma: &[E], sigma_j: &[E]) -> bool {
        let mut ws = Workspace::new();
        self.update_ws(sigma, sigma_j, &mut ws)
    }

    /// Step direction p = −H g (forward iteration).
    pub fn direction(&self, g: &[E], out: &mut [E]) {
        self.h.apply(g, out);
        negate(out);
    }

    /// Step direction p = −H g with workspace scratch (allocation-free).
    pub fn direction_ws(&self, g: &[E], out: &mut [E], ws: &mut Workspace<E>) {
        self.h.apply_into(g, out, ws);
        negate(out);
    }

    pub fn low_rank(&self) -> &LowRank<E> {
        &self.h
    }

    /// Dense materialization of B (test/diagnostic use only; widens to f64).
    pub fn dense_direct(&self) -> crate::linalg::dmat::DMat {
        let mut m = crate::linalg::dmat::DMat::eye(self.dim);
        for (a, b) in self.direct.rows() {
            for r in 0..self.dim {
                for c in 0..self.dim {
                    m[(r, c)] += a[r].to_f64() * b[c].to_f64();
                }
            }
        }
        m
    }
}

impl<E: Elem> InvOp<E> for AdjointBroyden<E> {
    fn dim(&self) -> usize {
        self.dim
    }
    fn apply(&self, x: &[E], out: &mut [E]) {
        self.h.apply(x, out)
    }
    fn apply_t(&self, x: &[E], out: &mut [E]) {
        self.h.apply_t(x, out)
    }
    fn apply_into(&self, x: &[E], out: &mut [E], ws: &mut Workspace<E>) {
        self.h.apply_into(x, out, ws)
    }
    fn apply_t_into(&self, x: &[E], out: &mut [E], ws: &mut Workspace<E>) {
        self.h.apply_t_into(x, out, ws)
    }
    fn apply_multi(&self, xs: &[E], out: &mut [E]) {
        self.h.apply_multi(xs, out)
    }
    fn apply_t_multi(&self, xs: &[E], out: &mut [E]) {
        self.h.apply_t_multi(xs, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dmat::DMat;
    use crate::linalg::lu::Lu;
    use crate::util::prop;

    #[test]
    fn adjoint_secant_condition() {
        // After update(σ, σᵀJ):  σᵀ B_{n+1} = σᵀ J.
        prop::check("adjbroyden-secant", 20, |rng| {
            let n = 3 + rng.below(10);
            let j = DMat::randn(n, n, 1.0, rng);
            let mut ab = AdjointBroyden::new(n, 32, MemoryPolicy::Freeze);
            for _ in 0..4 {
                let sigma = rng.normal_vec(n);
                let mut sigma_j = vec![0.0; n];
                j.matvec_t(&sigma, &mut sigma_j); // σᵀJ = (Jᵀσ)ᵀ
                if ab.update(&sigma, &sigma_j) {
                    let mut sb = vec![0.0; n];
                    ab.left_apply_direct(&sigma, &mut sb);
                    prop::ensure_close_vec(&sb, &sigma_j, 1e-8, "σᵀB = σᵀJ")?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn adjoint_identity_on_inverse() {
        // ⟨Hx, y⟩ == ⟨x, Hᵀy⟩ for the maintained inverse estimate — mirrors
        // broyden.rs's transpose_apply_consistent for the adjoint family.
        prop::check("adjbroyden-adjoint-identity", 15, |rng| {
            let n = 4 + rng.below(8);
            let j = DMat::randn(n, n, 1.0, rng);
            let mut ab = AdjointBroyden::new(n, 32, MemoryPolicy::Freeze);
            for _ in 0..5 {
                let sigma = rng.normal_vec(n);
                let mut sigma_j = vec![0.0; n];
                j.matvec_t(&sigma, &mut sigma_j);
                ab.update(&sigma, &sigma_j);
            }
            let x = rng.normal_vec(n);
            let y = rng.normal_vec(n);
            let lhs = dot(&ab.apply_vec(&x), &y);
            let rhs = dot(&x, &ab.apply_t_vec(&y));
            prop::ensure_close(lhs, rhs, 1e-10, "adjoint identity")
        });
    }

    #[test]
    fn update_ws_matches_update() {
        prop::check("adjbroyden-update-ws", 8, |rng| {
            let n = 6;
            let j = DMat::randn(n, n, 1.0, rng);
            let mut a = AdjointBroyden::new(n, 16, MemoryPolicy::Freeze);
            let mut b = AdjointBroyden::new(n, 16, MemoryPolicy::Freeze);
            let mut ws = Workspace::new();
            for _ in 0..5 {
                let sigma = rng.normal_vec(n);
                let mut sigma_j = vec![0.0; n];
                j.matvec_t(&sigma, &mut sigma_j);
                let ra = a.update(&sigma, &sigma_j);
                let rb = b.update_ws(&sigma, &sigma_j, &mut ws);
                prop::ensure(ra == rb, "same accept/skip decision")?;
            }
            let x = rng.normal_vec(n);
            prop::ensure_close_vec(&a.apply_vec(&x), &b.apply_vec(&x), 1e-14, "same operator")
        });
    }

    #[test]
    fn inverse_tracks_direct() {
        // H must equal B⁻¹ exactly (Sherman–Morrison bookkeeping).
        prop::check("adjbroyden-inverse", 15, |rng| {
            let n = 3 + rng.below(8);
            let j = DMat::randn(n, n, 1.0, rng);
            let mut ab = AdjointBroyden::new(n, 32, MemoryPolicy::Freeze);
            for _ in 0..5 {
                let sigma = rng.normal_vec(n);
                let mut sigma_j = vec![0.0; n];
                j.matvec_t(&sigma, &mut sigma_j);
                ab.update(&sigma, &sigma_j);
            }
            let b_dense = ab.dense_direct();
            let b_inv = match Lu::factor(&b_dense) {
                Ok(lu) => lu.inverse(),
                Err(_) => return Ok(()),
            };
            let x = rng.normal_vec(n);
            let mut want = vec![0.0; n];
            b_inv.matvec(&x, &mut want);
            prop::ensure_close_vec(&ab.apply_vec(&x), &want, 1e-6, "H = B⁻¹")
        });
    }

    #[test]
    fn apply_multi_matches_columnwise() {
        prop::check("adjbroyden-multi", 8, |rng| {
            let n = 6;
            let k = 3;
            let j = DMat::randn(n, n, 1.0, rng);
            let mut ab = AdjointBroyden::new(n, 16, MemoryPolicy::Freeze);
            for _ in 0..5 {
                let sigma = rng.normal_vec(n);
                let mut sigma_j = vec![0.0; n];
                j.matvec_t(&sigma, &mut sigma_j);
                ab.update(&sigma, &sigma_j);
            }
            let xs: Vec<f64> = (0..k * n).map(|_| rng.normal()).collect();
            let mut got = vec![0.0; k * n];
            ab.apply_multi(&xs, &mut got);
            for r in 0..k {
                let want = ab.apply_vec(&xs[r * n..(r + 1) * n]);
                prop::ensure_close_vec(&got[r * n..(r + 1) * n], &want, 1e-12, "multi col")?;
            }
            ab.apply_t_multi(&xs, &mut got);
            for r in 0..k {
                let want = ab.apply_t_vec(&xs[r * n..(r + 1) * n]);
                prop::ensure_close_vec(&got[r * n..(r + 1) * n], &want, 1e-12, "multi_t col")?;
            }
            Ok(())
        });
    }

    #[test]
    fn opa_direction_improves_left_inverse() {
        // The whole point of OPA (Thm 4): after an extra update in direction
        // σ = (∇L B⁻ᵀ)... the left-application σᵀB matches σᵀJ, hence
        // ∇Lᵀ B⁻¹ ≈ ∇Lᵀ J⁻¹ in that direction. Verify error decreases.
        prop::check("adjbroyden-opa", 10, |rng| {
            let n = 8;
            let j = DMat::random_spd(n, 0.5, 4.0, rng);
            let lu = Lu::factor(&j).unwrap();
            let grad = rng.normal_vec(n);
            let exact = lu.solve_t(&grad); // J⁻ᵀ ∇L

            let mut ab = AdjointBroyden::new(n, 32, MemoryPolicy::Freeze);
            // a couple of generic updates first
            for _ in 0..2 {
                let sigma = rng.normal_vec(n);
                let mut sigma_j = vec![0.0; n];
                j.matvec_t(&sigma, &mut sigma_j);
                ab.update(&sigma, &sigma_j);
            }
            let before = {
                let approx = ab.apply_t_vec(&grad);
                crate::linalg::vecops::dist2(&approx, &exact)
            };
            // OPA extra update: σ = Hᵀ ∇L  (v_nᵀ = ∇L B⁻¹  ⇒ v_n = B⁻ᵀ ∇L)
            let sigma = ab.apply_t_vec(&grad);
            let mut sigma_j = vec![0.0; n];
            j.matvec_t(&sigma, &mut sigma_j);
            ab.update(&sigma, &sigma_j);
            let after = {
                let approx = ab.apply_t_vec(&grad);
                crate::linalg::vecops::dist2(&approx, &exact)
            };
            prop::ensure(
                after <= before + 1e-12,
                &format!("OPA did not improve: before={before:.3e} after={after:.3e}"),
            )
        });
    }

    #[test]
    fn f32_guard_rejects_unrepresentable_sigma() {
        // σ tiny-but-nonzero: ‖a‖ = 1/‖σ‖ overflows f32, so the update must
        // be skipped instead of writing inf factors into the panels.
        let mut ab: AdjointBroyden<f32> = AdjointBroyden::new(3, 4, MemoryPolicy::Freeze);
        let sigma = [1e-40f32, 0.0, 0.0];
        let sigma_j = [2e-40f32, 0.0, 0.0];
        assert!(!ab.update(&sigma, &sigma_j));
        assert_eq!(ab.skipped, 1);
        assert_eq!(ab.rank(), 0);
        // A healthy σ is still accepted and the operator stays finite.
        assert!(ab.update(&[1.0, 0.0, 0.0], &[2.0, 0.0, 0.0]));
        let y = ab.apply_vec(&[1.0f32, 1.0, 1.0]);
        assert!(y.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn memory_freeze() {
        let mut ab = AdjointBroyden::new(4, 1, MemoryPolicy::Freeze);
        let j = DMat::eye(4);
        let sigma = vec![1.0, 0.0, 0.0, 0.0];
        let mut sigma_j = vec![0.0; 4];
        j.matvec_t(&sigma, &mut sigma_j);
        // First update has zero correction (B starts at I and J = I) —
        // becomes a no-op rank push; use a scaled J to force a real update.
        let j2 = DMat::from_rows(&[
            &[2.0, 0.0, 0.0, 0.0],
            &[0.0, 2.0, 0.0, 0.0],
            &[0.0, 0.0, 2.0, 0.0],
            &[0.0, 0.0, 0.0, 2.0],
        ]);
        j2.matvec_t(&sigma, &mut sigma_j);
        assert!(ab.update(&sigma, &sigma_j));
        assert!(!ab.update(&[0.0, 1.0, 0.0, 0.0], &[0.0, 2.0, 0.0, 0.0]));
        assert_eq!(ab.rank(), 1);
    }
}
