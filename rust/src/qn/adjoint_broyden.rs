//! Adjoint Broyden method (Schlenkrich, Griewank & Walther 2010) with the
//! paper's OPA secant condition (§2.3, eqs. (7)–(8); Theorem 4).
//!
//! The direct update for a direction σ is
//!
//! ```text
//! B_{n+1} = B_n + σ (σᵀ(J(z_{n+1}) − B_n)) / ‖σ‖²
//! ```
//!
//! which enforces the *adjoint* secant condition  σᵀ B_{n+1} = σᵀ J(z_{n+1}).
//! OPA chooses σ = v_n with v_nᵀ = ∇_z L(z_n) B_n⁻¹ — the exact direction in
//! which the hypergradient formula (3) applies the inverse Jacobian from the
//! left. The row σᵀJ is obtained with one VJP (auto-diff in the DEQ case,
//! an analytic Hessian-vector product in the bi-level case) — the extra cost
//! the paper notes for this method.
//!
//! We maintain **both** the direct factors (B = I + Σ aᵢbᵢᵀ, needed to form
//! σᵀB_n) and the inverse (H = B⁻¹, via Sherman–Morrison) so SHINE can apply
//! H and Hᵀ in O(m·d).

use crate::linalg::vecops::{dot, nrm2};
use crate::qn::low_rank::LowRank;
use crate::qn::{InvOp, MemoryPolicy};

#[derive(Clone, Debug)]
pub struct AdjointBroyden {
    dim: usize,
    /// Direct low-rank factors: B = I + Σ a_i b_iᵀ.
    a_facs: Vec<Vec<f64>>,
    b_facs: Vec<Vec<f64>>,
    /// Inverse estimate maintained by Sherman–Morrison.
    h: LowRank,
    max_mem: usize,
    pub denom_eps: f64,
    pub skipped: usize,
}

impl AdjointBroyden {
    pub fn new(dim: usize, max_mem: usize, policy: MemoryPolicy) -> Self {
        AdjointBroyden {
            dim,
            a_facs: Vec::new(),
            b_facs: Vec::new(),
            h: LowRank::identity(dim, max_mem, policy),
            max_mem,
            denom_eps: 1e-10,
            skipped: 0,
        }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn rank(&self) -> usize {
        self.a_facs.len()
    }

    /// out = σᵀ B_n  (row-vector result stored as a plain vector).
    pub fn left_apply_direct(&self, sigma: &[f64], out: &mut [f64]) {
        out.copy_from_slice(sigma);
        for i in 0..self.a_facs.len() {
            let c = dot(&self.a_facs[i], sigma);
            if c != 0.0 {
                crate::linalg::vecops::axpy(c, &self.b_facs[i], out);
            }
        }
    }

    /// Update with direction σ and the row `sigma_j = σᵀ J(z_{n+1})`
    /// (computed by the caller through a VJP). Returns false if skipped.
    pub fn update(&mut self, sigma: &[f64], sigma_j: &[f64]) -> bool {
        let ns2 = dot(sigma, sigma);
        if ns2 <= 1e-300 {
            self.skipped += 1;
            return false;
        }
        if self.a_facs.len() >= self.max_mem {
            // Freeze (mirror of the Broyden forward behaviour): both the
            // direct and inverse stacks stop growing together.
            self.skipped += 1;
            return false;
        }
        // c = σᵀJ − σᵀB  (the row correction)
        let mut c = vec![0.0; self.dim];
        self.left_apply_direct(sigma, &mut c);
        for i in 0..self.dim {
            c[i] = sigma_j[i] - c[i];
        }
        // a = σ / ‖σ‖²
        let a: Vec<f64> = sigma.iter().map(|&x| x / ns2).collect();
        // Sherman–Morrison for the inverse: denom = 1 + cᵀ H a.
        let ha = self.h.apply_vec(&a);
        let denom = 1.0 + dot(&c, &ha);
        if denom.abs() <= self.denom_eps * (1.0 + nrm2(&c) * nrm2(&ha)) {
            self.skipped += 1;
            return false;
        }
        let cth = self.h.apply_t_vec(&c); // (cᵀ H)ᵀ = Hᵀ c
        let u: Vec<f64> = ha.iter().map(|&x| -x / denom).collect();
        self.h.push(u, cth);
        self.a_facs.push(a);
        self.b_facs.push(c);
        true
    }

    /// Step direction p = −H g (forward iteration).
    pub fn direction(&self, g: &[f64], out: &mut [f64]) {
        self.h.apply(g, out);
        for v in out.iter_mut() {
            *v = -*v;
        }
    }

    pub fn low_rank(&self) -> &LowRank {
        &self.h
    }

    /// Dense materialization of B (test/diagnostic use only).
    pub fn dense_direct(&self) -> crate::linalg::dmat::DMat {
        let mut m = crate::linalg::dmat::DMat::eye(self.dim);
        for i in 0..self.a_facs.len() {
            for r in 0..self.dim {
                for c in 0..self.dim {
                    m[(r, c)] += self.a_facs[i][r] * self.b_facs[i][c];
                }
            }
        }
        m
    }
}

impl InvOp for AdjointBroyden {
    fn dim(&self) -> usize {
        self.dim
    }
    fn apply(&self, x: &[f64], out: &mut [f64]) {
        self.h.apply(x, out)
    }
    fn apply_t(&self, x: &[f64], out: &mut [f64]) {
        self.h.apply_t(x, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dmat::DMat;
    use crate::linalg::lu::Lu;
    use crate::util::prop;

    #[test]
    fn adjoint_secant_condition() {
        // After update(σ, σᵀJ):  σᵀ B_{n+1} = σᵀ J.
        prop::check("adjbroyden-secant", 20, |rng| {
            let n = 3 + rng.below(10);
            let j = DMat::randn(n, n, 1.0, rng);
            let mut ab = AdjointBroyden::new(n, 32, MemoryPolicy::Freeze);
            for _ in 0..4 {
                let sigma = rng.normal_vec(n);
                let mut sigma_j = vec![0.0; n];
                j.matvec_t(&sigma, &mut sigma_j); // σᵀJ = (Jᵀσ)ᵀ
                if ab.update(&sigma, &sigma_j) {
                    let mut sb = vec![0.0; n];
                    ab.left_apply_direct(&sigma, &mut sb);
                    prop::ensure_close_vec(&sb, &sigma_j, 1e-8, "σᵀB = σᵀJ")?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn inverse_tracks_direct() {
        // H must equal B⁻¹ exactly (Sherman–Morrison bookkeeping).
        prop::check("adjbroyden-inverse", 15, |rng| {
            let n = 3 + rng.below(8);
            let j = DMat::randn(n, n, 1.0, rng);
            let mut ab = AdjointBroyden::new(n, 32, MemoryPolicy::Freeze);
            for _ in 0..5 {
                let sigma = rng.normal_vec(n);
                let mut sigma_j = vec![0.0; n];
                j.matvec_t(&sigma, &mut sigma_j);
                ab.update(&sigma, &sigma_j);
            }
            let b_dense = ab.dense_direct();
            let b_inv = match Lu::factor(&b_dense) {
                Ok(lu) => lu.inverse(),
                Err(_) => return Ok(()),
            };
            let x = rng.normal_vec(n);
            let mut want = vec![0.0; n];
            b_inv.matvec(&x, &mut want);
            prop::ensure_close_vec(&ab.apply_vec(&x), &want, 1e-6, "H = B⁻¹")
        });
    }

    #[test]
    fn opa_direction_improves_left_inverse() {
        // The whole point of OPA (Thm 4): after an extra update in direction
        // σ = (∇L B⁻ᵀ)... the left-application σᵀB matches σᵀJ, hence
        // ∇Lᵀ B⁻¹ ≈ ∇Lᵀ J⁻¹ in that direction. Verify error decreases.
        prop::check("adjbroyden-opa", 10, |rng| {
            let n = 8;
            let j = DMat::random_spd(n, 0.5, 4.0, rng);
            let lu = Lu::factor(&j).unwrap();
            let grad = rng.normal_vec(n);
            let exact = lu.solve_t(&grad); // J⁻ᵀ ∇L

            let mut ab = AdjointBroyden::new(n, 32, MemoryPolicy::Freeze);
            // a couple of generic updates first
            for _ in 0..2 {
                let sigma = rng.normal_vec(n);
                let mut sigma_j = vec![0.0; n];
                j.matvec_t(&sigma, &mut sigma_j);
                ab.update(&sigma, &sigma_j);
            }
            let before = {
                let approx = ab.apply_t_vec(&grad);
                crate::linalg::vecops::dist2(&approx, &exact)
            };
            // OPA extra update: σ = Hᵀ ∇L  (v_nᵀ = ∇L B⁻¹  ⇒ v_n = B⁻ᵀ ∇L)
            let sigma = ab.apply_t_vec(&grad);
            let mut sigma_j = vec![0.0; n];
            j.matvec_t(&sigma, &mut sigma_j);
            ab.update(&sigma, &sigma_j);
            let after = {
                let approx = ab.apply_t_vec(&grad);
                crate::linalg::vecops::dist2(&approx, &exact)
            };
            prop::ensure(
                after <= before + 1e-12,
                &format!("OPA did not improve: before={before:.3e} after={after:.3e}"),
            )
        });
    }

    #[test]
    fn memory_freeze() {
        let mut ab = AdjointBroyden::new(4, 1, MemoryPolicy::Freeze);
        let j = DMat::eye(4);
        let sigma = vec![1.0, 0.0, 0.0, 0.0];
        let mut sigma_j = vec![0.0; 4];
        j.matvec_t(&sigma, &mut sigma_j);
        // First update has zero correction (B starts at I and J = I) —
        // becomes a no-op rank push; use a scaled J to force a real update.
        let j2 = DMat::from_rows(&[
            &[2.0, 0.0, 0.0, 0.0],
            &[0.0, 2.0, 0.0, 0.0],
            &[0.0, 0.0, 2.0, 0.0],
            &[0.0, 0.0, 0.0, 2.0],
        ]);
        j2.matvec_t(&sigma, &mut sigma_j);
        assert!(ab.update(&sigma, &sigma_j));
        assert!(!ab.update(&[0.0, 1.0, 0.0, 0.0], &[0.0, 2.0, 0.0, 0.0]));
        assert_eq!(ab.rank(), 1);
    }
}
