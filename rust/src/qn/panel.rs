//! Contiguous factor storage for identity-plus-low-rank operators.
//!
//! [`FactorPanel`] keeps the rank-one factors of `H = I + Σᵢ uᵢ vᵢᵀ` in two
//! flat row-major panels (`m × d` each) backed by a ring buffer, generic
//! over **two independent storage precisions** — one per panel side
//! (`FactorPanel<EU, EV>`, with `EV` defaulting to `EU` so the historical
//! single-precision spelling `FactorPanel<E>` is unchanged). f32 panels
//! serve the DEQ path, f64 the bi-level experiments, and the half-width
//! [`crate::linalg::vecops::Bf16`]/[`crate::linalg::vecops::F16`] storages
//! the reduced-precision serving tier; the **mixed layout**
//! `FactorPanel<Bf16, f32>` keeps the U factors (the error-cheap
//! accumulation side of `Hᵀ x = x + V (Uᵀ x)`… the side only ever summed
//! into under an f64 accumulator) in bf16 while the V factors — the
//! coefficient-sweep side whose dot products set every coefficient — stay
//! f32. See the precision contract in [`crate::linalg::vecops`]:
//!
//! * **apply is one linear sweep** — the kernels in
//!   [`crate::linalg::vecops`] (`panel_gemv` / `panel_gemv_t`) stream the
//!   panels front to back, so the O(m·d) low-rank application that SHINE's
//!   speed claim rests on (PAPER §2.1, Fig. 3) runs at memory bandwidth
//!   instead of chasing `Vec<Vec<f64>>` pointers — and at half the bytes
//!   per element in the f32 instantiation;
//! * **evict is O(1)** — replacing the oldest factor overwrites one row and
//!   bumps the ring head, where the old representation paid an O(m·d)
//!   `Vec::remove(0)` memmove per eviction;
//! * **pushes are allocation-free at steady state** — storage grows
//!   geometrically up to the fixed capacity while the rank's high-water mark
//!   rises; once it stops rising (or the ring is full), pushing factors
//!   inside a solver loop never touches the allocator.
//!
//! Invariant: `head != 0` only once the ring is full (`len == cap`), so the
//! *physical* rows `0..len` are always exactly the live factors. Summation
//! order does not matter for `H x`, which lets the kernels ignore the ring
//! structure entirely; logical (oldest → newest) order is available through
//! [`FactorPanel::row`] / [`FactorPanel::phys`] for the update rules that
//! need it (L-BFGS two-loop recursion).

use crate::linalg::vecops::Elem;

/// Flat row-major storage of up to `cap` factor pairs `(uᵢ, vᵢ)` of
/// dimension `dim`, with the u-panel in storage precision `EU` and the
/// v-panel in `EV` (defaulting to `EU` — `FactorPanel<f32>` is the
/// homogeneous f32 panel it always was; `FactorPanel<Bf16, f32>` is the
/// mixed serving layout). Backing storage grows geometrically up to `cap`
/// as rows are pushed (callers routinely pass generous caps like
/// `max_iters + 64`, which would be gigabytes if allocated eagerly at
/// DEQ-scale `dim`); once the high-water mark is reached, pushes never
/// allocate again.
#[derive(Clone, Debug)]
pub struct FactorPanel<EU: Elem = f64, EV: Elem = EU> {
    dim: usize,
    cap: usize,
    len: usize,
    /// Ring start: logical row 0 lives at physical row `head`.
    head: usize,
    /// Row-major panel of u-factors (allocated rows × dim).
    u: Vec<EU>,
    /// Row-major panel of v-factors (allocated rows × dim).
    v: Vec<EV>,
}

impl<EU: Elem, EV: Elem> FactorPanel<EU, EV> {
    /// Create a panel for up to `cap` factors of dimension `dim`.
    pub fn new(dim: usize, cap: usize) -> FactorPanel<EU, EV> {
        FactorPanel {
            dim,
            cap,
            len: 0,
            head: 0,
            u: Vec::new(),
            v: Vec::new(),
        }
    }

    /// Scratch size for a coefficient buffer covering every live row, quantized
    /// to powers of two (bounded by `cap`) so repeated workspace takes keep a
    /// stable size while the rank grows.
    pub fn coeff_len(&self) -> usize {
        self.len.next_power_of_two().min(self.cap)
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn is_full(&self) -> bool {
        self.len >= self.cap
    }

    pub fn clear(&mut self) {
        self.len = 0;
        self.head = 0;
    }

    /// Physical row index of logical row `i` (0 = oldest).
    #[inline]
    pub fn phys(&self, i: usize) -> usize {
        debug_assert!(i < self.len);
        let p = self.head + i;
        if p >= self.cap {
            p - self.cap
        } else {
            p
        }
    }

    /// Logical row `i` (0 = oldest, `len-1` = newest) as `(uᵢ, vᵢ)` slices.
    #[inline]
    pub fn row(&self, i: usize) -> (&[EU], &[EV]) {
        let p = self.phys(i) * self.dim;
        (&self.u[p..p + self.dim], &self.v[p..p + self.dim])
    }

    /// Iterate rows in logical (oldest → newest) order.
    pub fn rows(&self) -> impl Iterator<Item = (&[EU], &[EV])> + '_ {
        (0..self.len).map(move |i| self.row(i))
    }

    /// The live portion of the u-panel as one contiguous `len × dim` block
    /// (physical order — valid for order-independent sweeps only).
    #[inline]
    pub fn u_flat(&self) -> &[EU] {
        &self.u[..self.len * self.dim]
    }

    /// The live portion of the v-panel as one contiguous `len × dim` block.
    #[inline]
    pub fn v_flat(&self) -> &[EV] {
        &self.v[..self.len * self.dim]
    }

    /// Claim the slot for a new newest factor, evicting the oldest in O(1)
    /// when full. Returns `(physical_row, u_slot, v_slot)`; the caller fills
    /// the slots in place. Allocation only happens while the storage
    /// high-water mark is still rising (geometric growth, bounded by `cap`);
    /// at steady state — ring full, or rank no longer growing — this never
    /// touches the allocator.
    pub fn advance(&mut self) -> (usize, &mut [EU], &mut [EV]) {
        assert!(self.cap > 0, "FactorPanel::advance on zero-capacity panel");
        let phys = if self.len < self.cap {
            // Ring is not full: head is still 0, rows are 0..len.
            debug_assert_eq!(self.head, 0);
            let p = self.len;
            self.len += 1;
            p
        } else {
            // Overwrite the oldest row and rotate the ring head.
            let p = self.head;
            self.head = if self.head + 1 >= self.cap {
                0
            } else {
                self.head + 1
            };
            p
        };
        let need = (phys + 1) * self.dim;
        if self.u.len() < need {
            let have_rows = if self.dim == 0 { 0 } else { self.u.len() / self.dim };
            let new_rows = (have_rows * 2).max(4).max(phys + 1).min(self.cap);
            self.u.resize(new_rows * self.dim, EU::ZERO);
            self.v.resize(new_rows * self.dim, EV::ZERO);
        }
        let o = phys * self.dim;
        (
            phys,
            &mut self.u[o..o + self.dim],
            &mut self.v[o..o + self.dim],
        )
    }

    /// Copy-push a factor pair (convenience over [`FactorPanel::advance`]).
    pub fn push(&mut self, u: &[EU], v: &[EV]) {
        debug_assert_eq!(u.len(), self.dim);
        debug_assert_eq!(v.len(), self.dim);
        let (_, us, vs) = self.advance();
        us.copy_from_slice(u);
        vs.copy_from_slice(v);
    }

    /// Change the capacity in place. Growing an unwrapped ring (`head == 0`)
    /// is O(1) — storage already grows lazily on demand; shrinking, or
    /// growing after the ring has wrapped, falls back to an O(m·d) rebuild
    /// that keeps the newest factors.
    pub fn resize_cap(&mut self, cap: usize) {
        if cap == self.cap {
            return;
        }
        if cap > self.cap && self.head == 0 {
            self.cap = cap;
            return;
        }
        *self = self.with_cap(cap);
    }

    /// Rebuild into a panel of capacity `cap`, keeping the newest
    /// `min(len, cap)` factors in logical order. O(m·d) — used only when a
    /// strategy resizes its memory budget, never inside a solver loop.
    pub fn with_cap(&self, cap: usize) -> FactorPanel<EU, EV> {
        let mut out = FactorPanel::new(self.dim, cap);
        let keep = self.len.min(cap);
        for i in (self.len - keep)..self.len {
            let (u, v) = self.row(i);
            out.push(u, v);
        }
        out
    }

    /// Re-store every live factor in the target precisions, preserving
    /// logical (oldest → newest) order and capacity. Each element widens to
    /// f64 and narrows once (round-to-nearest-even for the half-width
    /// storages) — this is the one sanctioned place a panel changes
    /// precision, used when the serving tier demotes a freshly calibrated
    /// estimate into its reduced-precision layout. O(m·d); never on a hot
    /// path.
    pub fn convert<FU: Elem, FV: Elem>(&self) -> FactorPanel<FU, FV> {
        let mut out: FactorPanel<FU, FV> = FactorPanel::new(self.dim, self.cap);
        for (u, v) in self.rows() {
            let (_, us, vs) = out.advance();
            for (dst, src) in us.iter_mut().zip(u) {
                *dst = FU::from_f64(src.to_f64());
            }
            for (dst, src) in vs.iter_mut().zip(v) {
                *dst = FV::from_f64(src.to_f64());
            }
        }
        out
    }
}

impl<E: Elem> FactorPanel<E, E> {
    /// Swap the u/v panels in place — the zero-copy transpose
    /// `(I + Σ u vᵀ)ᵀ = I + Σ v uᵀ`. Only defined for homogeneous panels:
    /// a mixed layout is orientation-specific by construction (the narrow
    /// side must stay the accumulation side), so transposing it requires an
    /// explicit [`FactorPanel::convert`].
    pub fn swap_uv(&mut self) {
        std::mem::swap(&mut self.u, &mut self.v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rowvec(p: &FactorPanel, i: usize) -> (Vec<f64>, Vec<f64>) {
        let (u, v) = p.row(i);
        (u.to_vec(), v.to_vec())
    }

    #[test]
    fn push_and_logical_order() {
        let mut p = FactorPanel::new(2, 3);
        assert!(p.is_empty());
        for k in 0..3 {
            p.push(&[k as f64, 0.0], &[0.0, k as f64]);
        }
        assert!(p.is_full());
        for k in 0..3 {
            let (u, v) = rowvec(&p, k);
            assert_eq!(u, vec![k as f64, 0.0]);
            assert_eq!(v, vec![0.0, k as f64]);
        }
    }

    #[test]
    fn ring_evicts_oldest_in_place() {
        let mut p = FactorPanel::new(1, 2);
        p.push(&[1.0], &[10.0]);
        p.push(&[2.0], &[20.0]);
        p.push(&[3.0], &[30.0]); // evicts 1.0
        assert_eq!(p.len(), 2);
        assert_eq!(rowvec(&p, 0).0, vec![2.0]);
        assert_eq!(rowvec(&p, 1).0, vec![3.0]);
        p.push(&[4.0], &[40.0]); // evicts 2.0
        assert_eq!(rowvec(&p, 0).0, vec![3.0]);
        assert_eq!(rowvec(&p, 1).0, vec![4.0]);
    }

    #[test]
    fn flat_views_cover_live_rows() {
        let mut p = FactorPanel::new(2, 2);
        p.push(&[1.0, 2.0], &[5.0, 6.0]);
        assert_eq!(p.u_flat(), &[1.0, 2.0]);
        p.push(&[3.0, 4.0], &[7.0, 8.0]);
        p.push(&[9.0, 9.0], &[9.0, 9.0]); // wraps: physical order now mixed
        assert_eq!(p.u_flat().len(), 4);
        // Sum over the flat view equals sum over logical rows.
        let flat_sum: f64 = p.u_flat().iter().sum();
        let logical_sum: f64 = p.rows().map(|(u, _)| u.iter().sum::<f64>()).sum();
        assert_eq!(flat_sum, logical_sum);
    }

    #[test]
    fn with_cap_keeps_newest() {
        let mut p = FactorPanel::new(1, 4);
        for k in 0..4 {
            p.push(&[k as f64], &[k as f64]);
        }
        let small = p.with_cap(2);
        assert_eq!(small.len(), 2);
        assert_eq!(rowvec(&small, 0).0, vec![2.0]);
        assert_eq!(rowvec(&small, 1).0, vec![3.0]);
        let big = p.with_cap(8);
        assert_eq!(big.len(), 4);
        assert_eq!(rowvec(&big, 0).0, vec![0.0]);
    }

    #[test]
    fn resize_cap_grow_and_shrink() {
        // Unwrapped ring: grow is in place, factors and order untouched.
        let mut p = FactorPanel::new(1, 2);
        p.push(&[1.0], &[1.0]);
        p.push(&[2.0], &[2.0]);
        p.resize_cap(5);
        assert_eq!(p.cap(), 5);
        assert_eq!(p.len(), 2);
        p.push(&[3.0], &[3.0]);
        assert_eq!(
            p.rows().map(|(u, _)| u[0]).collect::<Vec<_>>(),
            vec![1.0, 2.0, 3.0]
        );
        // Wrapped ring: grow rebuilds, keeping logical order.
        let mut w = FactorPanel::new(1, 2);
        for k in 0..3 {
            w.push(&[k as f64], &[k as f64]); // wraps: head != 0
        }
        w.resize_cap(4);
        assert_eq!(w.len(), 2);
        assert_eq!(
            w.rows().map(|(u, _)| u[0]).collect::<Vec<_>>(),
            vec![1.0, 2.0]
        );
        w.push(&[9.0], &[9.0]);
        assert_eq!(w.len(), 3);
        // Shrink keeps the newest.
        w.resize_cap(2);
        assert_eq!(
            w.rows().map(|(u, _)| u[0]).collect::<Vec<_>>(),
            vec![2.0, 9.0]
        );
    }

    #[test]
    fn swap_uv_transposes() {
        let mut p = FactorPanel::new(2, 2);
        p.push(&[1.0, 2.0], &[3.0, 4.0]);
        p.swap_uv();
        let (u, v) = p.row(0);
        assert_eq!(u, &[3.0, 4.0]);
        assert_eq!(v, &[1.0, 2.0]);
    }

    #[test]
    fn advance_returns_fillable_slots() {
        let mut p: FactorPanel = FactorPanel::new(3, 1);
        {
            let (phys, us, vs) = p.advance();
            assert_eq!(phys, 0);
            us.copy_from_slice(&[1.0, 2.0, 3.0]);
            vs.copy_from_slice(&[4.0, 5.0, 6.0]);
        }
        assert_eq!(p.row(0).0, &[1.0, 2.0, 3.0]);
        assert_eq!(p.row(0).1, &[4.0, 5.0, 6.0]);
    }

    #[test]
    fn mixed_panel_and_convert() {
        use crate::linalg::vecops::Bf16;
        // Mixed layout: bf16 u-side, f32 v-side. Dyadic values are exact in
        // both storages, so conversion round-trips bit-for-bit.
        let mut p: FactorPanel<Bf16, f32> = FactorPanel::new(2, 3);
        for k in 0..4 {
            // 4 pushes into cap 3: the oldest row evicts.
            let u: Vec<Bf16> = [k as f64, 0.5].iter().map(|&x| Bf16::from_f64(x)).collect();
            p.push(&u, &[k as f32, -0.25]);
        }
        assert_eq!(p.len(), 3);
        let (u0, v0) = p.row(0);
        assert_eq!(u0[0].to_f64(), 1.0);
        assert_eq!(v0[0], 1.0f32);
        // convert preserves logical order and capacity across precisions.
        let q: FactorPanel<f64, f64> = p.convert();
        assert_eq!(q.len(), 3);
        assert_eq!(q.cap(), 3);
        for (i, (u, v)) in q.rows().enumerate() {
            assert_eq!(u[0], (i + 1) as f64);
            assert_eq!(u[1], 0.5);
            assert_eq!(v[0], (i + 1) as f64);
            assert_eq!(v[1], -0.25);
        }
        // Narrowing back reproduces the original bits for dyadic values.
        let back: FactorPanel<Bf16, f32> = q.convert();
        for ((bu, bv), (pu, pv)) in back.rows().zip(p.rows()) {
            assert!(bu.iter().zip(pu).all(|(a, b)| a.to_bits() == b.to_bits()));
            assert_eq!(bv, pv);
        }
    }

    #[test]
    fn f32_panel_round_trips() {
        let mut p: FactorPanel<f32> = FactorPanel::new(2, 2);
        p.push(&[1.5, -2.0], &[0.5, 4.0]);
        p.push(&[3.0, 0.25], &[-1.0, 2.0]);
        p.push(&[7.0, 8.0], &[9.0, 10.0]); // evicts the first pair
        assert_eq!(p.len(), 2);
        assert_eq!(p.row(0).0, &[3.0f32, 0.25]);
        assert_eq!(p.row(1).1, &[9.0f32, 10.0]);
        p.swap_uv();
        assert_eq!(p.row(1).0, &[9.0f32, 10.0]);
    }
}
