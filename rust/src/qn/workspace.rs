//! Reusable scratch arena for the solver hot loops.
//!
//! Every quasi-Newton update and every solver iteration needs a handful of
//! d-length temporaries (`Hy`, `Hᵀs`, step/secant differences, …). The seed
//! implementation allocated fresh `Vec`s for each of them on every iteration;
//! [`Workspace`] replaces that with a small LIFO pool of buffers that are
//! checked out with [`Workspace::take`] and returned with
//! [`Workspace::give`]. After the first few iterations the pool capacities
//! stabilize and the loop performs **zero heap allocations** (verified by the
//! counting-allocator test in `rust/tests/qn_alloc.rs`).
//!
//! The arena is generic over the storage precision [`Elem`] and keeps **two
//! pools**, mirroring the crate's precision contract (see
//! [`crate::linalg::vecops`]):
//!
//! * the *storage pool* (`take`/`give`) hands out `Vec<E>` buffers for
//!   iterates, residuals and panel slots — f32 on the DEQ path, f64 on the
//!   bi-level path;
//! * the *accumulator pool* (`take_acc`/`give_acc`) hands out `Vec<f64>`
//!   buffers for reduction results — panel-sweep coefficients, two-loop
//!   α's, Anderson Gram systems — which stay in wide precision even when
//!   storage is f32.
//!
//! The arena is deliberately dumb: buffers are plain `Vec`s so callers keep
//! full-slice ergonomics, `take` zero-fills (an O(n) memset, negligible next
//! to the O(m·d) panel sweeps it brackets), and nothing is lifetime-tracked
//! — forgetting a `give` merely re-allocates on the next `take`. One
//! LIFO discipline matters for staying allocation-free: return buffers in
//! the reverse order you took them when their lengths differ, so the next
//! round of takes pops buffers whose capacity already fits.
//!
//! The serving path adds a third pool of `usize` buffers
//! ([`Workspace::take_idx`]/[`Workspace::give_idx`]): the batched
//! fixed-point solvers track which caller-side column each physical column
//! of the compacted state block holds (retired columns swap to the back),
//! and that permutation must live somewhere allocation-free too.

use crate::linalg::vecops::Elem;

/// LIFO pool of reusable buffers in storage precision `E`, plus a secondary
/// pool of `f64` accumulator buffers and a small pool of `usize` index
/// buffers (column permutations of the batched solvers).
#[derive(Clone, Debug)]
pub struct Workspace<E: Elem = f64> {
    pool: Vec<Vec<E>>,
    acc: Vec<Vec<f64>>,
    idx: Vec<Vec<usize>>,
}

impl<E: Elem> Workspace<E> {
    pub fn new() -> Workspace<E> {
        Workspace {
            pool: Vec::with_capacity(16),
            acc: Vec::with_capacity(8),
            idx: Vec::with_capacity(4),
        }
    }

    /// Check out a zero-filled storage buffer of length `n`. Reuses the most
    /// recently returned buffer when one is available (its capacity is kept
    /// across uses, so steady-state takes never allocate).
    pub fn take(&mut self, n: usize) -> Vec<E> {
        let mut b = self.pool.pop().unwrap_or_default();
        b.clear();
        b.resize(n, E::ZERO);
        b
    }

    /// Return a storage buffer to the pool for reuse.
    pub fn give(&mut self, b: Vec<E>) {
        self.pool.push(b);
    }

    /// Check out a zero-filled `f64` accumulator buffer of length `n` (for
    /// dot-product coefficients, Gram matrices, …). Same LIFO reuse as
    /// [`Workspace::take`], drawn from a separate pool so narrow storage
    /// buffers and wide accumulator buffers never alias.
    pub fn take_acc(&mut self, n: usize) -> Vec<f64> {
        let mut b = self.acc.pop().unwrap_or_default();
        b.clear();
        b.resize(n, 0.0);
        b
    }

    /// Return an accumulator buffer to the pool for reuse.
    pub fn give_acc(&mut self, b: Vec<f64>) {
        self.acc.push(b);
    }

    /// Check out a zero-filled `usize` index buffer of length `n` (column
    /// permutations of the batched solvers). Same LIFO reuse as
    /// [`Workspace::take`], drawn from its own pool.
    pub fn take_idx(&mut self, n: usize) -> Vec<usize> {
        let mut b = self.idx.pop().unwrap_or_default();
        b.clear();
        b.resize(n, 0);
        b
    }

    /// Return an index buffer to the pool for reuse.
    pub fn give_idx(&mut self, b: Vec<usize>) {
        self.idx.push(b);
    }

    /// Number of storage buffers currently parked in the pool.
    pub fn pooled(&self) -> usize {
        self.pool.len()
    }
}

impl<E: Elem> Default for Workspace<E> {
    fn default() -> Self {
        Workspace::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_zeroed_and_sized() {
        let mut ws = Workspace::new();
        let mut b = ws.take(5);
        assert_eq!(b, vec![0.0; 5]);
        b[0] = 7.0;
        ws.give(b);
        // Reuse must be re-zeroed even though the buffer is recycled.
        let b2 = ws.take(3);
        assert_eq!(b2, vec![0.0; 3]);
    }

    #[test]
    fn reuses_capacity() {
        let mut ws: Workspace = Workspace::new();
        let b = ws.take(100);
        let ptr = b.as_ptr();
        ws.give(b);
        let b2 = ws.take(50);
        // Same backing allocation serves the smaller request.
        assert_eq!(b2.as_ptr(), ptr);
        assert_eq!(ws.pooled(), 0);
        ws.give(b2);
        assert_eq!(ws.pooled(), 1);
    }

    #[test]
    fn acc_pool_is_separate() {
        // An f32 workspace still hands out f64 accumulator scratch, and the
        // two pools never mix.
        let mut ws: Workspace<f32> = Workspace::new();
        let s = ws.take(4);
        assert_eq!(s, vec![0.0f32; 4]);
        let a = ws.take_acc(4);
        assert_eq!(a, vec![0.0f64; 4]);
        ws.give(s);
        ws.give_acc(a);
        assert_eq!(ws.pooled(), 1);
        let a2 = ws.take_acc(2);
        assert_eq!(a2.len(), 2);
        // Storage pool untouched by the acc take.
        assert_eq!(ws.pooled(), 1);
    }

    #[test]
    fn idx_pool_recycles() {
        let mut ws: Workspace = Workspace::new();
        let mut ids = ws.take_idx(6);
        assert_eq!(ids, vec![0usize; 6]);
        ids[3] = 7;
        let ptr = ids.as_ptr();
        ws.give_idx(ids);
        // Recycled buffer is re-zeroed and reuses the same allocation.
        let ids2 = ws.take_idx(4);
        assert_eq!(ids2, vec![0usize; 4]);
        assert_eq!(ids2.as_ptr(), ptr);
        // Storage/acc pools untouched.
        assert_eq!(ws.pooled(), 0);
    }
}
