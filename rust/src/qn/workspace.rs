//! Reusable scratch arena for the solver hot loops.
//!
//! Every quasi-Newton update and every solver iteration needs a handful of
//! d-length temporaries (`Hy`, `Hᵀs`, step/secant differences, …). The seed
//! implementation allocated fresh `Vec`s for each of them on every iteration;
//! [`Workspace`] replaces that with a small LIFO pool of buffers that are
//! checked out with [`Workspace::take`] and returned with
//! [`Workspace::give`]. After the first few iterations the pool capacities
//! stabilize and the loop performs **zero heap allocations** (verified by the
//! counting-allocator test in `rust/tests/qn_alloc.rs`).
//!
//! The arena is deliberately dumb: buffers are plain `Vec<f64>` so callers
//! keep full-slice ergonomics, `take` zero-fills (an O(n) memset, negligible
//! next to the O(m·d) panel sweeps it brackets), and nothing is lifetime-
//! tracked — forgetting a `give` merely re-allocates on the next `take`.

/// LIFO pool of reusable `f64` buffers.
#[derive(Clone, Debug, Default)]
pub struct Workspace {
    pool: Vec<Vec<f64>>,
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace {
            pool: Vec::with_capacity(16),
        }
    }

    /// Check out a zero-filled buffer of length `n`. Reuses the most
    /// recently returned buffer when one is available (its capacity is kept
    /// across uses, so steady-state takes never allocate).
    pub fn take(&mut self, n: usize) -> Vec<f64> {
        let mut b = self.pool.pop().unwrap_or_default();
        b.clear();
        b.resize(n, 0.0);
        b
    }

    /// Return a buffer to the pool for reuse.
    pub fn give(&mut self, b: Vec<f64>) {
        self.pool.push(b);
    }

    /// Number of buffers currently parked in the pool.
    pub fn pooled(&self) -> usize {
        self.pool.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_zeroed_and_sized() {
        let mut ws = Workspace::new();
        let mut b = ws.take(5);
        assert_eq!(b, vec![0.0; 5]);
        b[0] = 7.0;
        ws.give(b);
        // Reuse must be re-zeroed even though the buffer is recycled.
        let b2 = ws.take(3);
        assert_eq!(b2, vec![0.0; 3]);
    }

    #[test]
    fn reuses_capacity() {
        let mut ws = Workspace::new();
        let b = ws.take(100);
        let ptr = b.as_ptr();
        ws.give(b);
        let b2 = ws.take(50);
        // Same backing allocation serves the smaller request.
        assert_eq!(b2.as_ptr(), ptr);
        assert_eq!(ws.pooled(), 0);
        ws.give(b2);
        assert_eq!(ws.pooled(), 1);
    }
}
