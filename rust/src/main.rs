//! `shine` — L3 coordinator CLI.
//!
//! Subcommands:
//!   list                          list registered experiments
//!   run <exp-id> [--seed N] [--quick] [--out results]
//!   run-all [--quick]             run every experiment in registry order
//!   train [--variant cifar] ...   ad-hoc DEQ training run
//!   hpo [--dataset news20] ...    ad-hoc bi-level HPO run
//!   serve-http [--addr ...] ...   HTTP/1.1 front over the sharded serving tier
//!   artifacts-check               load + execute every artifact once
//!   version

use shine::coordinator::{registry, run_experiment, ExpCtx};
use shine::linalg::vecops::Elem;
use shine::util::cli::Args;
use std::process::ExitCode;

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = if argv.is_empty() { &[][..] } else { &argv[1..] };
    match dispatch(cmd, rest) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn ctx_from(a: &Args) -> ExpCtx {
    ExpCtx {
        seed: a.get_u64("seed"),
        quick: a.get_bool("quick"),
        out_dir: a.get("out").to_string(),
        artifacts_dir: a.get("artifacts").to_string(),
    }
}

fn common_flags(args: Args) -> Args {
    args.flag("seed", "0", "base RNG seed")
        .switch("quick", "reduced sizes (smoke run)")
        .flag("out", "results", "output directory for result JSON")
        .flag(
            "artifacts",
            &shine::runtime::engine::Engine::default_dir(),
            "AOT artifact directory",
        )
}

fn dispatch(cmd: &str, rest: &[String]) -> anyhow::Result<()> {
    match cmd {
        "version" => {
            println!("shine {}", shine::version());
            Ok(())
        }
        "list" => {
            println!("{:<16} description", "id");
            for e in registry() {
                println!("{:<16} {}", e.id(), e.description());
            }
            Ok(())
        }
        "run" => {
            let a = common_flags(Args::new("shine run <exp-id>")).parse(rest)?;
            let id = a
                .positional()
                .first()
                .ok_or_else(|| anyhow::anyhow!("usage: shine run <exp-id> (see `shine list`)"))?
                .clone();
            let ctx = ctx_from(&a);
            run_experiment(&id, &ctx)?;
            Ok(())
        }
        "run-all" => {
            let a = common_flags(Args::new("shine run-all")).parse(rest)?;
            let ctx = ctx_from(&a);
            for e in registry() {
                eprintln!("== {} ==", e.id());
                if let Err(err) = run_experiment(e.id(), &ctx) {
                    eprintln!("experiment {} failed: {err:#}", e.id());
                }
            }
            Ok(())
        }
        "train" => {
            let a = common_flags(Args::new("shine train — ad-hoc DEQ training"))
                .flag("variant", "cifar", "model variant (tiny|cifar|imagenet)")
                .flag(
                    "backward",
                    "shine",
                    "backward strategy (original|original-limited|jacobian-free|shine|\
                     shine-fallback[:ratio]|shine-refine[:iters]|full[:iters]|\
                     adj-broyden|adj-broyden-opa)",
                )
                .flag("pretrain-steps", "20", "unrolled pretraining steps")
                .flag("steps", "50", "equilibrium training steps")
                .flag("lr", "1e-3", "base learning rate")
                .flag("n-train", "320", "training set size")
                .parse(rest)?;
            cmd_train(&a)
        }
        "hpo" => {
            let a = common_flags(Args::new("shine hpo — ad-hoc bi-level HPO"))
                .flag("dataset", "news20", "dataset (news20|realsim)")
                .flag(
                    "strategy",
                    "shine",
                    "hypergrad strategy (full[:iters] | shine | shine-refine[:iters] | \
                     shine-fallback[:ratio] | jacobian-free)",
                )
                .switch("opa", "enable OPA extra updates")
                .flag("outer-iters", "40", "outer iterations")
                .parse(rest)?;
            cmd_hpo(&a)
        }
        "report" => {
            let a =
                common_flags(Args::new("shine report — render tables from results/")).parse(rest)?;
            let text = shine::coordinator::report::render(a.get("out"))?;
            println!("{text}");
            Ok(())
        }
        "serve-bench" => {
            let a = Args::new("shine serve-bench — synthetic closed-loop DEQ serving load")
                .flag("d", "4096", "fixed-point dimension per request")
                .flag("block", "64", "dense mixing block width of the synthetic model")
                .flag("requests", "192", "requests served per batch-size case")
                .flag(
                    "batch-sizes",
                    "1,8,32",
                    "comma-separated batch widths (first = sequential baseline)",
                )
                .flag(
                    "solver",
                    "picard",
                    "forward solver spec (picard[:tau] | anderson[:m[,beta]] | broyden[:mem])",
                )
                .flag("tol", "1e-5", "forward residual tolerance")
                .flag(
                    "panel-precision",
                    "f32",
                    "estimate panel storage (f64 | f32 | bf16 | f16 | mixed); \
                     reduced variants keep f32 state and demote only the cached \
                     estimate's factor panels",
                )
                .flag(
                    "models",
                    "1",
                    "distinct models: >1 runs the routed multi-model workload \
                     (per-key engines + estimate cache behind one scheduler)",
                )
                .flag("seed", "0", "base RNG seed")
                .flag(
                    "arrivals",
                    "pareto",
                    "open-loop interarrival process for the continuous-vs-discrete \
                     tail-latency comparison (pareto | poisson | off)",
                )
                .flag("alpha", "2.5", "Pareto tail index (> 1; smaller = burstier)")
                .flag(
                    "rate",
                    "0",
                    "open-loop offered rate in req/s (0 = auto: 0.65x the measured \
                     closed-loop capacity at the widest batch)",
                )
                .flag(
                    "col-budget",
                    "64",
                    "continuous batching: iterations per block residency before a \
                     straggler is evicted for retry (0 disables eviction)",
                )
                .flag(
                    "shards",
                    "0",
                    "scheduler shards for the sharded front-door cell (0 = skip; \
                     N >= 1 replays the open-loop schedule through a ShardedRouter \
                     with N worker threads)",
                )
                .flag(
                    "swap-at",
                    "0",
                    "submission index at which model 0 rolls to a new version via \
                     the zero-downtime swap (0 = no swap; needs --shards >= 1)",
                )
                .switch(
                    "smoke",
                    "tiny sizes for CI (overrides d/block/requests/batch-sizes and \
                     adds a two-model routed case, a two-shard sharded cell with \
                     one mid-run version swap, and a bf16 reduced-precision cell \
                     gated on convergence + guard trip rate)",
                )
                .switch(
                    "chaos",
                    "replay a two-shard, two-model cell under a seeded fault plan \
                     (injected panics, NaN residuals, stragglers) with the circuit \
                     breaker armed; gates on zero lost requests, >= 1 worker \
                     respawn, fault-free convergence, and every breaker closed",
                )
                .switch(
                    "http",
                    "additionally replay the smoke (and, with --chaos, the chaos) \
                     cell through the full HTTP edge over loopback TCP — real \
                     sockets, lazy JSON, admission control — gating on the \
                     exactly-once reconciliation of client statuses, the server \
                     response ledger, and the router's typed outcomes",
                )
                .parse(rest)?;
            cmd_serve_bench(&a)
        }
        "serve-http" => {
            let a = Args::new("shine serve-http — HTTP/1.1 front for the sharded DEQ serving tier")
                .flag("addr", "127.0.0.1:8080", "listen address (host:port; port 0 = ephemeral)")
                .flag("shards", "2", "scheduler shards (worker threads) of the router")
                .flag("models", "2", "synthetic models registered up front (ids 0..models)")
                .flag("d", "256", "fixed-point dimension per request")
                .flag("block", "32", "dense mixing block width of the synthetic model")
                .flag(
                    "solver",
                    "picard",
                    "forward solver spec (picard[:tau] | anderson[:m[,beta]] | broyden[:mem])",
                )
                .flag("tol", "1e-5", "forward residual tolerance")
                .flag(
                    "panel-precision",
                    "f32",
                    "estimate panel storage (f64 | f32 | bf16 | f16 | mixed)",
                )
                .flag("max-batch", "8", "per-shard scheduler batch cap")
                .flag("max-wait", "1e-3", "partial-batch deadline, seconds")
                .flag("queue-cap", "256", "per-shard admission queue cap (429 beyond it)")
                .flag("workers", "4", "HTTP connection-handler threads")
                .flag(
                    "max-conn",
                    "64",
                    "admission budget: connections beyond it shed with an inline 429",
                )
                .flag("seed", "0", "model parameter seed")
                .flag(
                    "requests",
                    "0",
                    "exit once this many solve requests have been answered \
                     (0 = serve until killed)",
                )
                .parse(rest)?;
            cmd_serve_http(&a)
        }
        "artifacts-check" => {
            let a = common_flags(Args::new("shine artifacts-check")).parse(rest)?;
            cmd_artifacts_check(&a)
        }
        "help" | "--help" | "-h" => {
            println!(
                "shine {} — SHINE (ICLR 2022) reproduction\n\n\
                 commands:\n  \
                 list              list experiments (paper figures/tables)\n  \
                 run <id>          run one experiment -> results/<id>.json\n  \
                 run-all           run every experiment\n  \
                 report            render paper-style tables from results/\n  \
                 train             ad-hoc DEQ training\n  \
                 hpo               ad-hoc bi-level HPO\n  \
                 serve-bench       batched DEQ serving: closed-loop throughput + open-loop\n                    \
                 continuous-batching tail latency\n  \
                 serve-http        HTTP/1.1 front over the sharded router (POST /v1/solve,\n                    \
                 GET /healthz, GET /metrics)\n  \
                 artifacts-check   smoke-test every AOT artifact\n  \
                 version",
                shine::version()
            );
            Ok(())
        }
        other => anyhow::bail!("unknown command '{other}' (try `shine help`)"),
    }
}

/// `--backward` parsing: trainer-specific strategies (adjoint Broyden, the
/// legacy `original*` spellings) are named here; everything else goes
/// through the session API's [`BackwardSpec`] parser and is lowered with
/// `BackwardKind::from_spec`.
fn parse_backward(s: &str) -> anyhow::Result<shine::deq::trainer::BackwardKind> {
    use shine::deq::trainer::BackwardKind as B;
    use shine::solvers::session::BackwardSpec;
    Ok(match s {
        "original" => B::Original {
            tol: 1e-6,
            max_iters: 1000,
        },
        "original-limited" => B::Original {
            tol: 1e-6,
            max_iters: 5,
        },
        "adj-broyden" => B::AdjointBroyden { opa_freq: None },
        "adj-broyden-opa" => B::AdjointBroyden { opa_freq: Some(5) },
        other => B::from_spec(
            &BackwardSpec::parse(other)
                .map_err(|e| anyhow::anyhow!("--backward: {e}"))?,
        ),
    })
}

fn cmd_train(a: &Args) -> anyhow::Result<()> {
    use shine::data::synth_images::synth_images;
    use shine::deq::trainer::{Trainer, TrainerConfig};
    use shine::runtime::engine::Engine;
    use shine::util::rng::Rng;

    let eng = Engine::load(a.get("artifacts"))?;
    let variant = a.get("variant").to_string();
    eng.warmup_variant(&variant)?;
    let pretrain_steps = a.get_usize("pretrain-steps");
    let steps = a.get_usize("steps");
    let cfg = TrainerConfig {
        variant: variant.clone(),
        backward: parse_backward(a.get("backward"))?,
        lr: a.get_f64("lr"),
        total_steps: pretrain_steps + steps,
        seed: a.get_u64("seed"),
        ..Default::default()
    };
    let mut tr = Trainer::new(&eng, cfg)?;
    let v = tr.model.v.clone();
    let ds = synth_images(
        a.get_usize("n-train"),
        v.h,
        v.w,
        v.c_in,
        v.n_classes,
        0.5,
        a.get_u64("seed"),
    );
    let mut rng = Rng::new(a.get_u64("seed") ^ 1);
    let mut step = 0;
    eprintln!(
        "training {variant} DEQ ({} params, d={}) with {}",
        tr.params.n_params(),
        v.fixed_point_dim,
        tr.cfg.backward.name()
    );
    'pre: loop {
        for idx in ds.epoch_batches(v.batch, &mut rng) {
            if step >= pretrain_steps {
                break 'pre;
            }
            let (x, labels) = ds.batch(&idx);
            let loss = tr.pretrain_step(&x, &labels)?;
            println!("pretrain step {step}: loss {loss:.4}");
            step += 1;
        }
    }
    step = 0;
    'train: loop {
        for idx in ds.epoch_batches(v.batch, &mut rng) {
            if step >= steps {
                break 'train;
            }
            let (x, labels) = ds.batch(&idx);
            let s = tr.train_step(&x, &labels)?;
            println!(
                "step {step}: loss {:.4} (fwd {:.0}ms/{} iters, bwd {:.0}ms)",
                s.loss,
                s.fwd_seconds * 1e3,
                s.fwd_iters,
                s.bwd_seconds * 1e3
            );
            step += 1;
        }
    }
    let acc = tr.evaluate(&ds, 4, &mut rng)?;
    println!("final train-set accuracy (4 batches): {acc:.3}");
    Ok(())
}

fn cmd_hpo(a: &Args) -> anyhow::Result<()> {
    use shine::bilevel::hoag::{hoag_run, HoagOptions};
    use shine::data::split::split_logreg;
    use shine::data::synth_text::{synth_text, TextConfig};
    use shine::hypergrad::Strategy;
    use shine::problems::logreg::{LogRegInner, LogRegOuter};
    use shine::util::rng::Rng;

    let cfg = match a.get("dataset") {
        "news20" => TextConfig::news20_like(),
        "realsim" => TextConfig::realsim_like(),
        other => anyhow::bail!("unknown dataset '{other}'"),
    };
    let data = synth_text(&cfg, a.get_u64("seed"));
    let mut rng = Rng::new(a.get_u64("seed") ^ 2);
    let (train, val, test) = split_logreg(&data, &mut rng);
    let prob = LogRegInner { train };
    let outer = LogRegOuter { val, test };
    // `--strategy` is a session-API BackwardSpec; Strategy::from_spec
    // applies the bi-level stack's tolerance conventions.
    let strategy = Strategy::from_spec(
        &shine::solvers::session::BackwardSpec::parse(a.get("strategy"))
            .map_err(|e| anyhow::anyhow!("--strategy: {e}"))?,
    );
    let opts = HoagOptions {
        outer_iters: a.get_usize("outer-iters"),
        strategy,
        opa: if a.get_bool("opa") {
            Some(shine::qn::lbfgs::OpaConfig { freq: 5, t0: 1.0 })
        } else {
            None
        },
        ..Default::default()
    };
    let res = hoag_run(&prob, &outer, &[-4.0], &opts);
    for p in &res.trace {
        println!(
            "outer {:>3}: t={:.2}s theta={:+.3} val={:.4} test={:.4}",
            p.k, p.time, p.theta[0], p.val_loss, p.test_loss
        );
    }
    println!("final theta: {:+.4}", res.theta[0]);
    Ok(())
}

/// Monomorphization dispatch for `--panel-precision`: every variant runs
/// the identical generic body, differing only in the storage types of the
/// cached inverse estimates (see [`shine::solvers::session::PanelPrecision`]
/// for the mapping). The smoke run additionally pins a bf16
/// reduced-precision cell regardless of the flag.
fn cmd_serve_bench(a: &Args) -> anyhow::Result<()> {
    use shine::linalg::vecops::{Bf16, F16};
    use shine::solvers::session::PanelPrecision;

    let precision = PanelPrecision::parse(a.get("panel-precision"))
        .map_err(|e| anyhow::anyhow!("--panel-precision: {e}"))?;
    match precision {
        PanelPrecision::F64 => serve_bench_run::<f64, f64, f64>(a, precision)?,
        PanelPrecision::F32 => serve_bench_run::<f32, f32, f32>(a, precision)?,
        PanelPrecision::Bf16 => serve_bench_run::<f32, Bf16, Bf16>(a, precision)?,
        PanelPrecision::F16 => serve_bench_run::<f32, F16, F16>(a, precision)?,
        PanelPrecision::Mixed => serve_bench_run::<f32, Bf16, f32>(a, precision)?,
    }
    if a.get_bool("smoke") {
        smoke_reduced_precision(a)?;
    }
    if a.get_bool("chaos") {
        chaos_cell(a)?;
    }
    if a.get_bool("http") {
        http_smoke_cell(a)?;
        if a.get_bool("chaos") {
            http_chaos_cell(a)?;
        }
    }
    Ok(())
}

/// The serve-bench body at one panel-precision instantiation: `E` is the
/// state precision (requests, iterates, cotangents); `EU`/`EV` the storage
/// of every cached estimate's U/V factor panels.
fn serve_bench_run<E: Elem, EU: Elem, EV: Elem>(
    a: &Args,
    precision: shine::solvers::session::PanelPrecision,
) -> anyhow::Result<()> {
    use shine::serve::{
        run_open_loop, run_routed_closed_loop, run_sharded_open_loop, run_suite, Arrivals,
        EngineConfig, ModelKey, OpenLoopConfig, RecalibPolicy, RoutedLoadConfig, Router,
        ServeEngine, ShardedLoadConfig, SharedModel, SynthDeq,
    };
    use shine::solvers::session::SolverSpec;
    use std::sync::Arc;

    let smoke = a.get_bool("smoke");
    let d = if smoke { 256 } else { a.get_usize("d") };
    let block = if smoke { 32 } else { a.get_usize("block") };
    let total = if smoke { 48 } else { a.get_usize("requests") };
    let batch_sizes: Vec<usize> = if smoke {
        vec![1, 8]
    } else {
        a.get("batch-sizes")
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<usize>()
                    .map_err(|_| anyhow::anyhow!("bad batch size '{s}'"))
            })
            .collect::<Result<_, _>>()?
    };
    if batch_sizes.is_empty() {
        anyhow::bail!("need at least one batch size");
    }
    if block == 0 || d % block != 0 {
        anyhow::bail!("--block must divide --d");
    }
    let tol = a.get_f64("tol");
    let solver = SolverSpec::parse(a.get("solver"))
        .map_err(|e| anyhow::anyhow!("--solver: {e}"))?
        .with_tol(tol)
        .with_max_iters(200);
    let seed = a.get_u64("seed");
    // The smoke gate always exercises the routed two-model path on top of
    // the single-model suite.
    let models = if smoke { 2 } else { a.get_usize("models") };
    if models == 0 {
        anyhow::bail!("--models must be at least 1");
    }
    eprintln!(
        "serve-bench: d={d} block={block} requests/case={total} batch sizes {batch_sizes:?} \
         solver={} panel-precision={} (first width is the sequential baseline)",
        solver.method.name(),
        precision.name()
    );
    let rows = run_suite::<E, EU, EV>(d, block, &batch_sizes, total, solver, seed);
    println!(
        "{:>6} {:>12} {:>10} {:>12} {:>12} {:>10} {:>6}",
        "B", "req/s", "speedup", "p50 ms", "p95 ms", "iters/req", "conv"
    );
    for row in &rows {
        let r = &row.report;
        println!(
            "{:>6} {:>12.1} {:>9.2}x {:>12.3} {:>12.3} {:>10.1} {:>6}",
            row.b,
            r.rps,
            row.speedup_vs_baseline,
            r.p50_latency_ms,
            r.p95_latency_ms,
            r.fwd_iters_mean,
            if r.all_converged { "yes" } else { "NO" }
        );
    }
    // Hard failure, not a warning: the CI smoke step gates on this exit
    // code, so a serving-path convergence regression must turn the run red.
    if let Some(bad) = rows.iter().find(|r| !r.report.all_converged) {
        anyhow::bail!(
            "batch width {} had unconverged columns (tol {tol})",
            bad.b
        );
    }

    // Open-loop tail-latency comparison: the same arrival schedule through
    // continuous batching (the default serving mode) and through discrete
    // batch formation. Continuous is the headline number; discrete is the
    // baseline it must beat on p95 under bursty arrivals.
    let arrivals_kind = a.get("arrivals");
    if arrivals_kind != "off" {
        let bsz = *batch_sizes.iter().max().expect("non-empty");
        let rate_flag = a.get_f64("rate");
        let rate = if rate_flag > 0.0 {
            rate_flag
        } else {
            // Auto: offer 65% of the measured closed-loop capacity at the
            // widest batch — busy but stable, so the queueing tail is real
            // without the backlog growing unboundedly.
            0.65 * rows.last().expect("non-empty").report.rps
        };
        let arrivals = match arrivals_kind {
            "poisson" => Arrivals::Poisson { rate },
            "pareto" => Arrivals::Pareto {
                rate,
                alpha: a.get_f64("alpha"),
            },
            other => anyhow::bail!("--arrivals must be pareto, poisson or off (got '{other}')"),
        };
        let cb = a.get_usize("col-budget");
        let col_budget = if cb == 0 { None } else { Some(cb) };
        let model: SynthDeq<E> = SynthDeq::new(d, block, seed);
        let mk_engine = |col_budget| {
            let mut e: ServeEngine<E, EU, EV> = ServeEngine::new(
                d,
                EngineConfig {
                    max_batch: bsz,
                    solver,
                    calib: SolverSpec::broyden(30).with_tol(tol).with_max_iters(60),
                    fallback_ratio: None,
                    recalib: None,
                    col_budget,
                    breaker: None,
                },
            );
            e.calibrate(
                |z: &[E], out: &mut [E]| model.residual_batch(z, 1, out),
                &vec![E::ZERO; d],
            );
            e
        };
        eprintln!(
            "open-loop: {arrivals_kind} arrivals at {rate:.1} req/s, B={bsz}, \
             col-budget {col_budget:?}"
        );
        println!(
            "{:>12} {:>10} {:>10} {:>10} {:>10} {:>8} {:>8} {:>6}",
            "mode", "p50 ms", "p95 ms", "p99 ms", "width", "sweeps", "evict", "conv"
        );
        let mut reps = Vec::with_capacity(2);
        for continuous in [true, false] {
            let lc = OpenLoopConfig {
                total,
                arrivals,
                max_batch: bsz,
                max_wait: 1e-3,
                continuous,
            };
            let mut engine = mk_engine(if continuous { col_budget } else { None });
            let rep = run_open_loop(&mut engine, &model, &lc, seed);
            println!(
                "{:>12} {:>10.3} {:>10.3} {:>10.3} {:>10.2} {:>8} {:>8} {:>6}",
                rep.mode,
                rep.p50_latency_ms,
                rep.p95_latency_ms,
                rep.p99_latency_ms,
                rep.mean_width,
                rep.sweeps,
                rep.evictions,
                if rep.all_converged { "yes" } else { "NO" }
            );
            if !rep.all_converged {
                anyhow::bail!("open-loop {} mode had unconverged requests (tol {tol})", rep.mode);
            }
            reps.push(rep);
        }
        let (cont, disc) = (&reps[0], &reps[1]);
        println!(
            "continuous vs discrete p95: {:.3} ms vs {:.3} ms ({:+.1}%)",
            cont.p95_latency_ms,
            disc.p95_latency_ms,
            100.0 * (cont.p95_latency_ms - disc.p95_latency_ms) / disc.p95_latency_ms.max(1e-9)
        );
    }

    if models > 1 {
        // Routed multi-model workload: N synthetic models (distinct
        // parameters) behind one keyed scheduler, per-key engines with a
        // per-key calibration-estimate cache and trip-rate re-calibration.
        let bsz = *batch_sizes.iter().max().expect("non-empty");
        let cfg = EngineConfig {
            max_batch: bsz,
            solver,
            calib: SolverSpec::broyden(30).with_tol(tol).with_max_iters(60),
            fallback_ratio: Some(10.0),
            recalib: Some(RecalibPolicy::default()),
            col_budget: None,
            breaker: None,
        };
        cfg.validate().map_err(|e| anyhow::anyhow!("routed engine config: {e}"))?;
        let mut router: Router<E, EU, EV> = Router::new(cfg);
        let keys: Vec<ModelKey> = (0..models as u32).map(|m| ModelKey::new(m, 0)).collect();
        for &k in &keys {
            let (it, rn) =
                router.register(k, Box::new(SynthDeq::<E>::new(d, block, seed ^ k.model as u64)));
            eprintln!("  routed: calibrated {k} in {it} iters (residual {rn:.2e})");
        }
        let lc = RoutedLoadConfig {
            clients_per_model: bsz,
            total,
            max_batch: bsz,
            max_wait: 1e-3,
        };
        let rep = run_routed_closed_loop(&mut router, &keys, &lc, seed ^ 0x2007);
        println!(
            "routed {models} models: {:.1} req/s over {} batches (p50 {:.3} ms, p95 {:.3} ms, \
             {} re-calibrations)",
            rep.rps, rep.batches, rep.p50_latency_ms, rep.p95_latency_ms, rep.recalibrations
        );
        for (k, n) in &rep.per_key_requests {
            println!("  {k}: {n} requests");
        }
        if !rep.all_converged {
            anyhow::bail!("routed workload had unconverged columns (tol {tol})");
        }
    }

    // Sharded front door: the same open-loop discipline through a
    // ShardedRouter with N worker shards (key-affinity routing, work
    // stealing, zero-downtime version swap). The smoke run pins a
    // two-shard, two-model cell with one mid-run swap and gates hard on
    // it — convergence, full schedule served, and a completed cutover.
    let shards = if smoke { 2 } else { a.get_usize("shards") };
    let swap_at = if smoke { total / 2 } else { a.get_usize("swap-at") };
    if shards > 0 {
        let bsz = *batch_sizes.iter().max().expect("non-empty");
        let engine_cfg = EngineConfig {
            max_batch: bsz,
            solver,
            calib: SolverSpec::broyden(30).with_tol(tol).with_max_iters(60),
            fallback_ratio: Some(10.0),
            recalib: Some(RecalibPolicy::default()),
            col_budget: None,
            breaker: None,
        };
        engine_cfg
            .validate()
            .map_err(|e| anyhow::anyhow!("sharded engine config: {e}"))?;
        let sharded_models = models.max(2);
        let mk = move |m: u32, v: u32| -> SharedModel<E> {
            Arc::new(SynthDeq::<E>::new(
                d,
                block,
                seed ^ m as u64 ^ ((v as u64) << 32),
            ))
        };
        // Oversaturate the offered rate so the measured req/s reflects the
        // router's aggregate capacity, not the arrival schedule.
        let rate = 4.0 * rows.last().expect("non-empty").report.rps;
        let lc = ShardedLoadConfig {
            shards,
            models: sharded_models,
            total,
            arrivals: Arrivals::Poisson { rate },
            max_batch: bsz,
            max_wait: 1e-3,
            hot_share: None,
            swap_at: if (1..total).contains(&swap_at) {
                Some(swap_at)
            } else {
                None
            },
            deadline: None,
        };
        eprintln!(
            "sharded: {shards} shards, {sharded_models} models, poisson {rate:.1} req/s, \
             swap at {:?}",
            lc.swap_at
        );
        let rep = run_sharded_open_loop::<E, EU, EV>(engine_cfg, &mk, &lc, seed ^ 0x5A4D);
        println!(
            "sharded {shards}x: {:.1} req/s (p50 {:.3} ms, p99 {:.3} ms, {} steals, \
             {} calibrations, {} re-calibrations)",
            rep.rps,
            rep.p50_latency_ms,
            rep.p99_latency_ms,
            rep.steals,
            rep.calibrations,
            rep.recalibrations
        );
        for (i, n) in rep.per_shard_served.iter().enumerate() {
            println!("  shard {i}: {n} requests");
        }
        if let Some(sw) = rep.swap {
            println!(
                "  swap requested at #{}: first new-version submission {:?}, \
                 {} served old / {} served new, cutover completed: {}",
                sw.requested_at, sw.cutover_at, sw.old_served, sw.new_served, sw.completed
            );
        }
        if rep.requests != total {
            anyhow::bail!("sharded cell served {}/{} requests", rep.requests, total);
        }
        if !rep.all_converged {
            anyhow::bail!("sharded workload had unconverged columns (tol {tol})");
        }
        if let Some(sw) = rep.swap {
            if !sw.completed {
                anyhow::bail!("live swap never cut over to the new version");
            }
            if sw.old_served == 0 {
                anyhow::bail!(
                    "zero-downtime swap served nothing on the old version — \
                     the roll was not actually live"
                );
            }
        }
    }
    Ok(())
}

/// The CI smoke gate's reduced-precision cell: the routed two-model closed
/// loop through a `Router<f32, Bf16, Bf16>` and a two-shard open loop
/// through the matching `ShardedRouter`, both with the §3 fallback guard
/// armed. Gates hard on the issue's acceptance criteria: every column
/// converges AND the guard trip rate never exceeds the recalibration
/// policy's bound (no bf16 estimate may degrade enough to go stale on
/// healthy traffic).
fn smoke_reduced_precision(a: &Args) -> anyhow::Result<()> {
    use shine::linalg::vecops::Bf16;
    use shine::serve::{
        run_routed_closed_loop, run_sharded_open_loop, Arrivals, EngineConfig, ModelKey,
        RecalibPolicy, RoutedLoadConfig, Router, ShardedLoadConfig, SharedModel, SynthDeq,
    };
    use shine::solvers::session::SolverSpec;
    use std::sync::Arc;

    // The pinned smoke geometry (matches the main smoke body).
    let (d, block, total, bsz) = (256, 32, 48, 8);
    let tol = a.get_f64("tol");
    let solver = SolverSpec::parse(a.get("solver"))
        .map_err(|e| anyhow::anyhow!("--solver: {e}"))?
        .with_tol(tol)
        .with_max_iters(200);
    let seed = a.get_u64("seed");
    let policy = RecalibPolicy::default();
    let cfg = EngineConfig {
        max_batch: bsz,
        solver,
        calib: SolverSpec::broyden(30).with_tol(tol).with_max_iters(60),
        fallback_ratio: Some(10.0),
        recalib: Some(policy),
        col_budget: None,
        breaker: None,
    };
    cfg.validate()
        .map_err(|e| anyhow::anyhow!("bf16 smoke engine config: {e}"))?;
    eprintln!("smoke: bf16 reduced-precision cell (guard armed, trip-rate bound {})",
        policy.trip_rate);
    let mut router: Router<f32, Bf16, Bf16> = Router::new(cfg);
    let keys: Vec<ModelKey> = (0..2u32).map(|m| ModelKey::new(m, 0)).collect();
    for &k in &keys {
        router.register(k, Box::new(SynthDeq::<f32>::new(d, block, seed ^ k.model as u64)));
    }
    let lc = RoutedLoadConfig {
        clients_per_model: bsz,
        total,
        max_batch: bsz,
        max_wait: 1e-3,
    };
    let rep = run_routed_closed_loop(&mut router, &keys, &lc, seed ^ 0xB16);
    println!(
        "bf16 routed: {:.1} req/s over {} batches ({} re-calibrations)",
        rep.rps, rep.batches, rep.recalibrations
    );
    if !rep.all_converged {
        anyhow::bail!("bf16 routed smoke cell had unconverged columns (tol {tol})");
    }
    for &k in &keys {
        let tr = router.engine(k).expect("registered key").trip_rate();
        if tr > policy.trip_rate {
            anyhow::bail!(
                "bf16 routed smoke cell: key {k} guard trip rate {tr:.3} exceeds \
                 the {} bound",
                policy.trip_rate
            );
        }
    }
    if rep.recalibrations != 0 {
        anyhow::bail!(
            "bf16 routed smoke cell: {} estimates went stale on healthy traffic",
            rep.recalibrations
        );
    }
    let mk = move |m: u32, v: u32| -> SharedModel<f32> {
        Arc::new(SynthDeq::<f32>::new(
            d,
            block,
            seed ^ m as u64 ^ ((v as u64) << 32),
        ))
    };
    let slc = ShardedLoadConfig {
        shards: 2,
        models: 2,
        total,
        arrivals: Arrivals::Poisson { rate: 50_000.0 },
        max_batch: bsz,
        max_wait: 1e-3,
        hot_share: Some(0.75),
        swap_at: None,
        deadline: None,
    };
    let srep = run_sharded_open_loop::<f32, Bf16, Bf16>(cfg, &mk, &slc, seed ^ 0xB16);
    println!(
        "bf16 sharded 2x: {:.1} req/s ({} steals, {} calibrations, {} re-calibrations)",
        srep.rps, srep.steals, srep.calibrations, srep.recalibrations
    );
    if srep.requests != total {
        anyhow::bail!("bf16 sharded smoke cell served {}/{total} requests", srep.requests);
    }
    if !srep.all_converged {
        anyhow::bail!("bf16 sharded smoke cell had unconverged columns (tol {tol})");
    }
    if srep.recalibrations != 0 {
        anyhow::bail!(
            "bf16 sharded smoke cell: {} estimates went stale on healthy traffic",
            srep.recalibrations
        );
    }
    Ok(())
}

/// The CI chaos gate: a two-shard, two-model sharded open loop replayed
/// under a seeded [`FaultPlan`] — injected model panics, NaN residual
/// columns, and straggler delays — with the hardened §3 guard and the
/// per-key circuit breaker armed. Victims are drawn from the first half of
/// the schedule so the healthy tail must close any breaker the faults
/// opened. Gates hard, in order: every submission resolves to exactly one
/// typed outcome (zero lost, zero shed), the injected panic actually killed
/// and respawned a worker, every injected fault surfaced as a typed
/// failure, every fault-free request converged, and no breaker is still
/// open at the end.
fn chaos_cell(a: &Args) -> anyhow::Result<()> {
    use shine::serve::{
        run_sharded_open_loop_with, Arrivals, BreakerConfig, EngineConfig, FaultPlan,
        RecalibPolicy, ShardedLoadConfig, SharedModel, SynthDeq,
    };
    use shine::solvers::session::SolverSpec;
    use std::sync::Arc;

    // The pinned chaos geometry (matches the smoke cells).
    let (d, block, total, bsz) = (256, 32, 48, 8);
    let (panics, nans, straggles) = (1, 2, 1);
    let tol = a.get_f64("tol");
    let solver = SolverSpec::parse(a.get("solver"))
        .map_err(|e| anyhow::anyhow!("--solver: {e}"))?
        .with_tol(tol)
        .with_max_iters(200);
    let seed = a.get_u64("seed");
    let cfg = EngineConfig {
        max_batch: bsz,
        solver,
        calib: SolverSpec::broyden(30).with_tol(tol).with_max_iters(60),
        fallback_ratio: Some(10.0),
        recalib: Some(RecalibPolicy::default()),
        col_budget: None,
        breaker: Some(BreakerConfig {
            threshold: 2,
            cooldown: 2,
        }),
    };
    cfg.validate()
        .map_err(|e| anyhow::anyhow!("chaos engine config: {e}"))?;
    // Victims drawn from the first half of the schedule: the clean tail
    // forces every opened breaker through half-open back to closed.
    let plan = FaultPlan::seeded(seed ^ 0xC4A05, total / 2, panics, nans, straggles);
    let mk = move |m: u32, v: u32| -> SharedModel<f64> {
        Arc::new(SynthDeq::<f64>::new(
            d,
            block,
            seed ^ m as u64 ^ ((v as u64) << 32),
        ))
    };
    let lc = ShardedLoadConfig {
        shards: 2,
        models: 2,
        total,
        arrivals: Arrivals::Poisson { rate: 50_000.0 },
        max_batch: bsz,
        max_wait: 1e-3,
        hot_share: None,
        swap_at: None,
        deadline: None,
    };
    eprintln!(
        "chaos: 2 shards, 2 models, fault plan {panics} panic / {nans} NaN / \
         {straggles} straggler over {total} requests (breaker threshold 2, cooldown 2)"
    );
    let rep =
        run_sharded_open_loop_with::<f64, f64, f64>(cfg, &mk, &lc, Some(&plan), seed ^ 0xC4A05);
    let ok = rep.requests
        - rep.model_faults
        - rep.worker_lost
        - rep.unconverged
        - rep.deadline_exceeded;
    println!(
        "chaos 2x: {} resolved ({ok} ok, {} model faults, {} worker lost, {} unconverged), \
         {} respawns, {} retries, {} shed, {} breakers open at end",
        rep.requests,
        rep.model_faults,
        rep.worker_lost,
        rep.unconverged,
        rep.respawns,
        rep.retries,
        rep.shed,
        rep.open_breakers
    );
    if rep.requests + rep.shed != total {
        anyhow::bail!(
            "chaos cell lost requests: {} resolved + {} shed != {total} offered",
            rep.requests,
            rep.shed
        );
    }
    if rep.shed != 0 {
        anyhow::bail!("chaos cell shed {} submissions despite the retry budget", rep.shed);
    }
    if rep.respawns == 0 {
        anyhow::bail!("chaos cell saw no worker respawn — the injected panic never landed");
    }
    // Every injected panic/NaN victim must surface as a typed failure. A
    // NaN victim sharing the panicked batch resolves WorkerLost instead of
    // ModelFault (batch composition is timing-dependent), so the two
    // counts are gated jointly.
    if rep.model_faults + rep.worker_lost < panics + nans {
        anyhow::bail!(
            "chaos cell: {} typed failures for {} injected panic/NaN victims",
            rep.model_faults + rep.worker_lost,
            panics + nans
        );
    }
    if !rep.all_converged {
        anyhow::bail!("chaos cell had unconverged fault-free requests (tol {tol})");
    }
    if rep.open_breakers != 0 {
        anyhow::bail!(
            "chaos cell ended with {} circuit breakers still open",
            rep.open_breakers
        );
    }
    Ok(())
}

/// Monomorphization dispatch for `serve-http` (same mapping as
/// [`cmd_serve_bench`]): the network layer itself is precision-free — it
/// talks to an `Arc<dyn SolveBackend>` — only the gateway + router behind
/// it are instantiated per storage layout.
fn cmd_serve_http(a: &Args) -> anyhow::Result<()> {
    use shine::linalg::vecops::{Bf16, F16};
    use shine::solvers::session::PanelPrecision;

    let precision = PanelPrecision::parse(a.get("panel-precision"))
        .map_err(|e| anyhow::anyhow!("--panel-precision: {e}"))?;
    match precision {
        PanelPrecision::F64 => serve_http_run::<f64, f64, f64>(a, precision),
        PanelPrecision::F32 => serve_http_run::<f32, f32, f32>(a, precision),
        PanelPrecision::Bf16 => serve_http_run::<f32, Bf16, Bf16>(a, precision),
        PanelPrecision::F16 => serve_http_run::<f32, F16, F16>(a, precision),
        PanelPrecision::Mixed => serve_http_run::<f32, Bf16, f32>(a, precision),
    }
}

/// Boot router + gateway + HTTP server on `--addr` and serve until killed
/// (or until `--requests` solves have been answered, for scripted runs).
fn serve_http_run<E: Elem, EU: Elem, EV: Elem>(
    a: &Args,
    precision: shine::solvers::session::PanelPrecision,
) -> anyhow::Result<()> {
    use shine::http::{Gateway, HttpConfig, HttpServer, SolveBackend};
    use shine::serve::{
        BreakerConfig, EngineConfig, ModelKey, RecalibPolicy, RetryPolicy, SchedulerConfig,
        ShardConfig, ShardedRouter, SynthDeq,
    };
    use shine::solvers::session::SolverSpec;
    use std::sync::Arc;

    let d = a.get_usize("d");
    let block = a.get_usize("block");
    let shards = a.get_usize("shards");
    let models = a.get_usize("models");
    if block == 0 || d % block != 0 {
        anyhow::bail!("--block must divide --d");
    }
    if shards == 0 || models == 0 {
        anyhow::bail!("--shards and --models must be at least 1");
    }
    let tol = a.get_f64("tol");
    let solver = SolverSpec::parse(a.get("solver"))
        .map_err(|e| anyhow::anyhow!("--solver: {e}"))?
        .with_tol(tol)
        .with_max_iters(200);
    let seed = a.get_u64("seed");
    let max_batch = a.get_usize("max-batch");
    let engine = EngineConfig {
        max_batch,
        solver,
        calib: SolverSpec::broyden(30).with_tol(tol).with_max_iters(60),
        fallback_ratio: Some(10.0),
        recalib: Some(RecalibPolicy::default()),
        col_budget: None,
        breaker: Some(BreakerConfig {
            threshold: 2,
            cooldown: 2,
        }),
    };
    engine
        .validate()
        .map_err(|e| anyhow::anyhow!("serve-http engine config: {e}"))?;
    let sched = SchedulerConfig {
        max_batch,
        max_wait: a.get_f64("max-wait"),
        queue_cap: a.get_usize("queue-cap"),
    };
    let router: ShardedRouter<E, EU, EV> =
        ShardedRouter::try_new(ShardConfig::new(shards, engine, sched))
            .map_err(|e| anyhow::anyhow!("serve-http router config: {e}"))?;
    for m in 0..models as u32 {
        let live = router.register(
            ModelKey::new(m, 0),
            Arc::new(SynthDeq::<E>::new(d, block, seed ^ m as u64)),
        );
        if !live {
            anyhow::bail!("model {m} failed calibration and never went live");
        }
    }
    let gateway = Arc::new(Gateway::new(router, d, RetryPolicy::none()));
    let backend: Arc<dyn SolveBackend> = gateway.clone();
    let http = HttpConfig {
        workers: a.get_usize("workers"),
        max_connections: a.get_usize("max-conn"),
        ..HttpConfig::default()
    };
    let mut server = HttpServer::bind(backend, a.get("addr"), http)
        .map_err(|e| anyhow::anyhow!("bind {}: {e}", a.get("addr")))?;
    println!(
        "serve-http listening on http://{} — {shards} shards, {models} models, d={d}, \
         panel-precision={}",
        server.local_addr(),
        precision.name()
    );
    println!("  POST /v1/solve   {{\"model\", \"z0\"?, \"cotangent\", \"deadline_ms\"?}}");
    println!("  GET  /healthz    liveness + per-shard respawns + quarantined keys");
    println!("  GET  /metrics    text exposition (router, per-key, server counters)");
    let stop_after = a.get_usize("requests");
    loop {
        std::thread::sleep(std::time::Duration::from_millis(200));
        if stop_after > 0 && server.counters().requests() >= stop_after {
            break;
        }
    }
    server.shutdown();
    let (mut ok, mut total) = (0u64, 0u64);
    for (status, n) in server.counters().by_status() {
        total += n;
        if status == 200 {
            ok += n;
        }
    }
    println!("serve-http: answered {total} responses ({ok} ok); shutting down");
    Ok(())
}

/// The loopback-HTTP smoke gate: a two-shard, two-model open loop with a
/// mid-run zero-downtime swap, replayed through real TCP sockets. Gates
/// hard on the exactly-once reconciliation across all three ledgers:
/// every offered request gets exactly one client-observed response, the
/// server's per-status response counts match the client's, every solve is
/// a converged 200, and the swap cut over with traffic on both versions.
fn http_smoke_cell(a: &Args) -> anyhow::Result<()> {
    use shine::http::HttpConfig;
    use shine::serve::{
        run_http_open_loop, Arrivals, EngineConfig, HttpLoadConfig, RecalibPolicy, SharedModel,
        SynthDeq,
    };
    use shine::solvers::session::SolverSpec;
    use std::sync::Arc;

    // The pinned smoke geometry (matches the other smoke cells).
    let (d, block, total, bsz) = (256, 32, 48, 8);
    let tol = a.get_f64("tol");
    let solver = SolverSpec::parse(a.get("solver"))
        .map_err(|e| anyhow::anyhow!("--solver: {e}"))?
        .with_tol(tol)
        .with_max_iters(200);
    let seed = a.get_u64("seed");
    let cfg = EngineConfig {
        max_batch: bsz,
        solver,
        calib: SolverSpec::broyden(30).with_tol(tol).with_max_iters(60),
        fallback_ratio: Some(10.0),
        recalib: Some(RecalibPolicy::default()),
        col_budget: None,
        breaker: None,
    };
    cfg.validate()
        .map_err(|e| anyhow::anyhow!("http smoke engine config: {e}"))?;
    let mk = move |m: u32, v: u32| -> SharedModel<f64> {
        Arc::new(SynthDeq::<f64>::new(
            d,
            block,
            seed ^ m as u64 ^ ((v as u64) << 32),
        ))
    };
    let lc = HttpLoadConfig {
        shards: 2,
        models: 2,
        total,
        clients: 6,
        arrivals: Arrivals::Poisson { rate: 50_000.0 },
        max_batch: bsz,
        max_wait: 1e-3,
        queue_cap: None,
        hot_share: Some(0.75),
        swap_at: Some(total / 2),
        deadline_ms: None,
        http: HttpConfig::default(),
    };
    eprintln!(
        "http smoke: 2 shards, 2 models over loopback TCP, {} clients, swap at #{}",
        lc.clients,
        total / 2
    );
    let rep = run_http_open_loop::<f64, f64, f64>(cfg, &mk, &lc, None, seed ^ 0x177E);
    println!(
        "http 2x: {} responses ({} ok) at {:.1} req/s (p50 {:.3} ms, p95 {:.3} ms), \
         swap old/new {}/{}, server ledger {:?}",
        rep.requests,
        rep.ok,
        rep.rps,
        rep.p50_latency_ms,
        rep.p95_latency_ms,
        rep.old_served,
        rep.new_served,
        rep.server_responses
    );
    if rep.client_errors != 0 {
        anyhow::bail!("http smoke cell: {} transport errors", rep.client_errors);
    }
    if rep.requests != total {
        anyhow::bail!(
            "http smoke cell: {}/{total} offered requests got a response",
            rep.requests
        );
    }
    if rep.ok != total {
        anyhow::bail!(
            "http smoke cell: {} of {total} responses were not 200s on a fault-free run",
            total - rep.ok
        );
    }
    if !rep.all_converged {
        anyhow::bail!("http smoke cell had unconverged 200s (tol {tol})");
    }
    // Server ledger must reconcile exactly-once with the client ledger.
    let server_total: u64 = rep.server_responses.iter().map(|(_, n)| n).sum();
    let server_ok = rep
        .server_responses
        .iter()
        .find(|(s, _)| *s == 200)
        .map(|(_, n)| *n)
        .unwrap_or(0);
    if server_total != total as u64 || server_ok != rep.ok as u64 {
        anyhow::bail!(
            "http smoke cell: server ledger ({server_ok} ok / {server_total} total) does not \
             match the client ledger ({} ok / {} total)",
            rep.ok,
            rep.requests
        );
    }
    if !rep.swap_completed || rep.old_served == 0 || rep.new_served == 0 {
        anyhow::bail!(
            "http smoke cell: swap did not complete with traffic on both versions \
             (completed {}, old {}, new {})",
            rep.swap_completed,
            rep.old_served,
            rep.new_served
        );
    }
    if rep.orphans != 0 {
        anyhow::bail!("http smoke cell: {} orphaned responses", rep.orphans);
    }
    Ok(())
}

/// The loopback-HTTP chaos gate: the chaos cell's seeded fault plan —
/// injected panics and NaN columns — driven through steal + swap
/// machinery AND the full HTTP edge concurrently. Gates on the typed
/// status mapping end-to-end: every offered request resolves to exactly
/// one client-observed status, every 503 matches a router-ledger
/// WorkerLost casualty one-for-one, every injected victim surfaced as a
/// typed 5xx, supervision respawned the shard, the healthy tail closed
/// every breaker, and fault-free traffic converged.
fn http_chaos_cell(a: &Args) -> anyhow::Result<()> {
    use shine::http::HttpConfig;
    use shine::serve::{
        run_http_open_loop, Arrivals, BreakerConfig, EngineConfig, FaultPlan, HttpLoadConfig,
        RecalibPolicy, SharedModel, SynthDeq,
    };
    use shine::solvers::session::SolverSpec;
    use std::sync::Arc;

    let (d, block, total, bsz) = (256, 32, 48, 8);
    let (panics, nans, straggles) = (1, 2, 1);
    let tol = a.get_f64("tol");
    let solver = SolverSpec::parse(a.get("solver"))
        .map_err(|e| anyhow::anyhow!("--solver: {e}"))?
        .with_tol(tol)
        .with_max_iters(200);
    let seed = a.get_u64("seed");
    let cfg = EngineConfig {
        max_batch: bsz,
        solver,
        calib: SolverSpec::broyden(30).with_tol(tol).with_max_iters(60),
        fallback_ratio: Some(10.0),
        recalib: Some(RecalibPolicy::default()),
        col_budget: None,
        breaker: Some(BreakerConfig {
            threshold: 2,
            cooldown: 2,
        }),
    };
    cfg.validate()
        .map_err(|e| anyhow::anyhow!("http chaos engine config: {e}"))?;
    // Victims drawn from the first half of the schedule (gateway ids are
    // assigned in submission order), so the clean tail closes breakers
    // and the swap's background calibration runs against faulted traffic.
    let plan = FaultPlan::seeded(seed ^ 0xC4A05, total / 2, panics, nans, straggles);
    let mk = move |m: u32, v: u32| -> SharedModel<f64> {
        Arc::new(SynthDeq::<f64>::new(
            d,
            block,
            seed ^ m as u64 ^ ((v as u64) << 32),
        ))
    };
    let lc = HttpLoadConfig {
        shards: 2,
        models: 2,
        total,
        clients: 6,
        arrivals: Arrivals::Poisson { rate: 50_000.0 },
        max_batch: bsz,
        max_wait: 1e-3,
        queue_cap: None,
        // The hot-key skew keeps the steal machinery engaged while the
        // faults and the swap land.
        hot_share: Some(0.75),
        swap_at: Some(total / 2),
        deadline_ms: None,
        http: HttpConfig::default(),
    };
    eprintln!(
        "http chaos: 2 shards, 2 models over loopback TCP, fault plan {panics} panic / \
         {nans} NaN / {straggles} straggler, swap at #{} (steal + swap + faults concurrent)",
        total / 2
    );
    let rep = run_http_open_loop::<f64, f64, f64>(cfg, &mk, &lc, Some(&plan), seed ^ 0xC4A05);
    println!(
        "http chaos 2x: {} responses ({} ok, {} 502, {} 503, {} 422) at {:.1} req/s, \
         {} respawns, server ledger {:?}",
        rep.requests,
        rep.ok,
        rep.model_faults,
        rep.worker_lost,
        rep.unconverged,
        rep.rps,
        rep.respawns,
        rep.server_responses
    );
    if rep.client_errors != 0 {
        anyhow::bail!("http chaos cell: {} transport errors", rep.client_errors);
    }
    if rep.requests != total {
        anyhow::bail!(
            "http chaos cell lost requests: {}/{total} offered got a response",
            rep.requests
        );
    }
    let accounted =
        rep.ok + rep.queue_full + rep.unconverged + rep.model_faults + rep.worker_lost
            + rep.deadline_exceeded + rep.other_4xx;
    if accounted != total {
        anyhow::bail!(
            "http chaos cell: {accounted}/{total} responses carried a mapped status"
        );
    }
    if rep.respawns == 0 {
        anyhow::bail!("http chaos cell saw no worker respawn — the injected panic never landed");
    }
    if rep.worker_lost != rep.ledger_worker_lost {
        anyhow::bail!(
            "http chaos cell: {} client 503s vs {} router WorkerLost casualties — the \
             typed-outcome ledger must reconcile one-for-one",
            rep.worker_lost,
            rep.ledger_worker_lost
        );
    }
    if rep.model_faults + rep.worker_lost < panics + nans {
        anyhow::bail!(
            "http chaos cell: {} typed 5xx for {} injected panic/NaN victims",
            rep.model_faults + rep.worker_lost,
            panics + nans
        );
    }
    let server_total: u64 = rep.server_responses.iter().map(|(_, n)| n).sum();
    if server_total != total as u64 {
        anyhow::bail!(
            "http chaos cell: server wrote {server_total} responses for {total} offered"
        );
    }
    if !rep.all_converged {
        anyhow::bail!("http chaos cell had unconverged 200s (tol {tol})");
    }
    if rep.open_breakers != 0 {
        anyhow::bail!(
            "http chaos cell ended with {} circuit breakers still open",
            rep.open_breakers
        );
    }
    if rep.orphans != 0 {
        anyhow::bail!("http chaos cell: {} orphaned responses", rep.orphans);
    }
    Ok(())
}

fn cmd_artifacts_check(a: &Args) -> anyhow::Result<()> {
    use shine::deq::model::{DeqModel, Params};
    use shine::runtime::engine::Engine;
    use shine::util::rng::Rng;

    let eng = Engine::load(a.get("artifacts"))?;
    for vname in eng.manifest.variants.keys().cloned().collect::<Vec<_>>() {
        let m = DeqModel::new(&eng, &vname)?;
        let mut rng = Rng::new(1);
        let p = Params::init(&m.v, &mut rng);
        let d = m.v.fixed_point_dim;
        let x = rng.normal_vec_f32(m.v.batch * m.v.h * m.v.w * m.v.c_in, 1.0);
        let z = rng.normal_vec_f32(d, 1.0);
        let u = m.inject(&p, &x)?;
        let f = m.f(&p, &z, &u)?;
        let _ = m.f_vjp_z(&p, &z, &u, &f)?;
        let _ = m.head_logits(&p, &z)?;
        println!("variant {vname}: OK (d={d})");
    }
    println!("all artifacts OK");
    Ok(())
}
