//! Procedural class-templated image dataset (stand-in for CIFAR-10 /
//! ImageNet in the DEQ experiments — Fig. 3, Tables E.1–E.3).
//!
//! Each class k gets a smooth template built from a few random 2-D
//! sinusoidal components per channel (Gabor-like, so classes differ in
//! orientation/frequency content rather than raw pixel offsets). A sample is
//! `amplitude · T_k + σ · noise`, globally standardized. This gives a real
//! trainable classification task whose difficulty is controlled by σ, while
//! keeping the DEQ fixed-point dimension in the paper's regime.

use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct ImageDataset {
    /// row-major (n, h·w·c_in), f32, standardized
    pub images: Vec<f32>,
    pub labels: Vec<usize>,
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c_in: usize,
    pub n_classes: usize,
}

impl ImageDataset {
    pub fn sample_dim(&self) -> usize {
        self.h * self.w * self.c_in
    }

    pub fn image(&self, i: usize) -> &[f32] {
        let d = self.sample_dim();
        &self.images[i * d..(i + 1) * d]
    }

    /// Stack a batch of samples by index: returns (images, labels).
    pub fn batch(&self, idx: &[usize]) -> (Vec<f32>, Vec<usize>) {
        let d = self.sample_dim();
        let mut out = Vec::with_capacity(idx.len() * d);
        let mut labels = Vec::with_capacity(idx.len());
        for &i in idx {
            out.extend_from_slice(self.image(i));
            labels.push(self.labels[i]);
        }
        (out, labels)
    }

    /// Epoch iterator: shuffled, fixed-size batches (drops the remainder,
    /// like the paper's training loader).
    pub fn epoch_batches(&self, batch: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
        let perm = rng.permutation(self.n);
        perm.chunks(batch)
            .filter(|c| c.len() == batch)
            .map(|c| c.to_vec())
            .collect()
    }
}

/// Generate `n` images of shape (h, w, c_in) over `n_classes` classes.
pub fn synth_images(
    n: usize,
    h: usize,
    w: usize,
    c_in: usize,
    n_classes: usize,
    noise: f64,
    seed: u64,
) -> ImageDataset {
    let mut rng = Rng::new(seed ^ 0x1A6E5);
    let d = h * w * c_in;
    // Build class templates from 4 sinusoidal components per channel.
    let mut templates = vec![vec![0.0f64; d]; n_classes];
    for tpl in templates.iter_mut() {
        for c in 0..c_in {
            for _ in 0..4 {
                let fx = rng.uniform_in(0.5, 3.0);
                let fy = rng.uniform_in(0.5, 3.0);
                let phase = rng.uniform_in(0.0, std::f64::consts::TAU);
                let amp = rng.uniform_in(0.4, 1.0);
                for yy in 0..h {
                    for xx in 0..w {
                        let v = amp
                            * (fx * xx as f64 / w as f64 * std::f64::consts::TAU
                                + fy * yy as f64 / h as f64 * std::f64::consts::TAU
                                + phase)
                                .sin();
                        tpl[(yy * w + xx) * c_in + c] += v;
                    }
                }
            }
        }
    }
    let mut images = vec![0.0f32; n * d];
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let k = i % n_classes; // balanced classes
        let amp = rng.uniform_in(0.6, 1.4);
        for j in 0..d {
            images[i * d + j] = (amp * templates[k][j] + noise * rng.normal()) as f32;
        }
        labels.push(k);
    }
    // Global standardization.
    let mean: f64 = images.iter().map(|&v| v as f64).sum::<f64>() / images.len() as f64;
    let var: f64 = images
        .iter()
        .map(|&v| (v as f64 - mean) * (v as f64 - mean))
        .sum::<f64>()
        / images.len() as f64;
    let std = var.sqrt().max(1e-9);
    for v in images.iter_mut() {
        *v = ((*v as f64 - mean) / std) as f32;
    }
    // Shuffle sample order (labels were assigned round-robin).
    let perm = rng.permutation(n);
    let mut shuffled = vec![0.0f32; n * d];
    let mut shuffled_labels = vec![0usize; n];
    for (new_i, &old_i) in perm.iter().enumerate() {
        shuffled[new_i * d..(new_i + 1) * d].copy_from_slice(&images[old_i * d..(old_i + 1) * d]);
        shuffled_labels[new_i] = labels[old_i];
    }
    ImageDataset {
        images: shuffled,
        labels: shuffled_labels,
        n,
        h,
        w,
        c_in,
        n_classes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let a = synth_images(20, 8, 8, 3, 4, 0.3, 7);
        let b = synth_images(20, 8, 8, 3, 4, 0.3, 7);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.sample_dim(), 192);
        assert_eq!(a.images.len(), 20 * 192);
    }

    #[test]
    fn standardized() {
        let ds = synth_images(50, 8, 8, 3, 5, 0.4, 1);
        let mean: f64 = ds.images.iter().map(|&v| v as f64).sum::<f64>() / ds.images.len() as f64;
        assert!(mean.abs() < 1e-3, "mean={mean}");
    }

    #[test]
    fn classes_balanced() {
        let ds = synth_images(100, 4, 4, 3, 10, 0.2, 2);
        for k in 0..10 {
            let c = ds.labels.iter().filter(|&&l| l == k).count();
            assert_eq!(c, 10);
        }
    }

    #[test]
    fn batches_have_right_shape() {
        let ds = synth_images(33, 4, 4, 3, 3, 0.2, 3);
        let mut rng = Rng::new(0);
        let batches = ds.epoch_batches(8, &mut rng);
        assert_eq!(batches.len(), 4); // 33/8 -> 4 full batches
        let (imgs, labels) = ds.batch(&batches[0]);
        assert_eq!(imgs.len(), 8 * ds.sample_dim());
        assert_eq!(labels.len(), 8);
    }

    #[test]
    fn nearest_template_classification_beats_chance() {
        // The structure must be learnable: 1-NN to class means on a holdout
        // subset should beat 1/n_classes by a wide margin.
        let ds = synth_images(200, 8, 8, 3, 4, 0.5, 9);
        let d = ds.sample_dim();
        let mut means = vec![vec![0.0f64; d]; 4];
        let mut counts = [0usize; 4];
        for i in 0..100 {
            let k = ds.labels[i];
            counts[k] += 1;
            for j in 0..d {
                means[k][j] += ds.image(i)[j] as f64;
            }
        }
        for k in 0..4 {
            for j in 0..d {
                means[k][j] /= counts[k].max(1) as f64;
            }
        }
        let mut correct = 0;
        for i in 100..200 {
            let img = ds.image(i);
            let mut best = 0;
            let mut best_d = f64::INFINITY;
            for k in 0..4 {
                let dist: f64 = img
                    .iter()
                    .zip(&means[k])
                    .map(|(&a, &b)| (a as f64 - b) * (a as f64 - b))
                    .sum();
                if dist < best_d {
                    best_d = dist;
                    best = k;
                }
            }
            if best == ds.labels[i] {
                correct += 1;
            }
        }
        assert!(correct > 50, "1-NN correct = {correct}/100");
    }
}
