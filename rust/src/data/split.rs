//! Seeded train/val/test splitting (the paper splits 90%/5%/5% with a
//! different seed per run, Appendix C).

use crate::problems::logreg::LogRegData;
use crate::problems::nls::NlsData;
use crate::util::rng::Rng;

/// Return shuffled index sets of sizes (⌊n·f_train⌋, ⌊n·f_val⌋, rest).
pub fn split_indices(
    n: usize,
    f_train: f64,
    f_val: f64,
    rng: &mut Rng,
) -> (Vec<usize>, Vec<usize>, Vec<usize>) {
    assert!(f_train + f_val < 1.0 + 1e-12);
    let perm = rng.permutation(n);
    let n_train = (n as f64 * f_train).floor() as usize;
    let n_val = (n as f64 * f_val).floor() as usize;
    let train = perm[..n_train].to_vec();
    let val = perm[n_train..n_train + n_val].to_vec();
    let test = perm[n_train + n_val..].to_vec();
    (train, val, test)
}

/// Split a LogReg dataset 90/5/5 (paper's proportions).
pub fn split_logreg(
    data: &LogRegData,
    rng: &mut Rng,
) -> (LogRegData, LogRegData, LogRegData) {
    let (tr, va, te) = split_indices(data.n(), 0.90, 0.05, rng);
    let pick = |idx: &[usize]| LogRegData {
        x: data.x.select_rows(idx),
        y: idx.iter().map(|&i| data.y[i]).collect(),
    };
    (pick(&tr), pick(&va), pick(&te))
}

/// Split an NLS dataset 90/5/5.
pub fn split_nls(data: &NlsData, rng: &mut Rng) -> (NlsData, NlsData, NlsData) {
    let (tr, va, te) = split_indices(data.n(), 0.90, 0.05, rng);
    let pick = |idx: &[usize]| NlsData {
        x: data.x.select_rows(idx),
        y: idx.iter().map(|&i| data.y[i]).collect(),
    };
    (pick(&tr), pick(&va), pick(&te))
}

/// Convert ±1 LogReg labels to {0,1} NLS labels (shared generators).
pub fn logreg_to_nls(data: &LogRegData) -> NlsData {
    NlsData {
        x: data.x.clone(),
        y: data.y.iter().map(|&v| if v > 0.0 { 1.0 } else { 0.0 }).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_text::{synth_text, TextConfig};

    #[test]
    fn sizes_and_disjointness() {
        let mut rng = Rng::new(4);
        let (tr, va, te) = split_indices(100, 0.9, 0.05, &mut rng);
        assert_eq!(tr.len(), 90);
        assert_eq!(va.len(), 5);
        assert_eq!(te.len(), 5);
        let mut all: Vec<usize> = tr.iter().chain(&va).chain(&te).copied().collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 100);
    }

    #[test]
    fn split_logreg_partitions_rows() {
        let cfg = TextConfig {
            n_docs: 80,
            n_features: 100,
            n_informative: 10,
            len_lo: 5,
            len_hi: 15,
            zipf_a: 1.1,
            label_noise: 0.0,
            seed: 0,
        };
        let data = synth_text(&cfg, 0);
        let mut rng = Rng::new(1);
        let (tr, va, te) = split_logreg(&data, &mut rng);
        assert_eq!(tr.n() + va.n() + te.n(), 80);
        assert_eq!(tr.x.cols, 100);
    }

    #[test]
    fn nls_labels_are_01() {
        let cfg = TextConfig {
            n_docs: 30,
            n_features: 50,
            n_informative: 5,
            len_lo: 5,
            len_hi: 10,
            zipf_a: 1.1,
            label_noise: 0.0,
            seed: 0,
        };
        let data = synth_text(&cfg, 0);
        let nls = logreg_to_nls(&data);
        assert!(nls.y.iter().all(|&v| v == 0.0 || v == 1.0));
    }
}
