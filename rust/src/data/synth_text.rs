//! Sparse text-like synthetic dataset (stand-in for 20news / real-sim).
//!
//! Generative model, chosen to preserve what makes the paper's HPO problem
//! interesting (regularization genuinely matters, Hessian ill-conditioned):
//! * token frequencies follow a Zipf law (exponent ≈ 1.1), so a few features
//!   are dense columns and the tail is very sparse — like tf-idf text;
//! * a sparse ground-truth direction w* over `n_informative` features
//!   determines labels through a noisy logistic model;
//! * document lengths are heterogeneous (uniform in [len_lo, len_hi]);
//! * rows are l2-normalized (tf-idf convention), labels in {−1, +1}.

use crate::linalg::csr::Csr;
use crate::problems::logreg::LogRegData;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct TextConfig {
    pub n_docs: usize,
    pub n_features: usize,
    pub n_informative: usize,
    pub len_lo: usize,
    pub len_hi: usize,
    /// Zipf exponent for token draws.
    pub zipf_a: f64,
    /// label noise: probability of flipping a label
    pub label_noise: f64,
    pub seed: u64,
}

impl TextConfig {
    /// 20news-like regime: d ≫ n, very sparse (Fig. 1 left panel analogue).
    pub fn news20_like() -> Self {
        TextConfig {
            n_docs: 1500,
            n_features: 5000,
            n_informative: 250,
            len_lo: 30,
            len_hi: 120,
            zipf_a: 1.1,
            label_noise: 0.05,
            seed: 0,
        }
    }

    /// real-sim-like regime: n > d (Fig. 1 right panel analogue).
    pub fn realsim_like() -> Self {
        TextConfig {
            n_docs: 4000,
            n_features: 2500,
            n_informative: 200,
            len_lo: 25,
            len_hi: 90,
            zipf_a: 1.05,
            label_noise: 0.08,
            seed: 1,
        }
    }
}

/// Generate the dataset. Deterministic in `cfg.seed` ⊕ `seed`.
pub fn synth_text(cfg: &TextConfig, seed: u64) -> LogRegData {
    let mut rng = Rng::new(cfg.seed ^ seed.wrapping_mul(0xA24BAED4963EE407));
    let d = cfg.n_features;
    // Ground-truth direction on a random informative subset, biased toward
    // the frequent (low-index, by Zipf) region so most documents contain at
    // least some informative tokens — otherwise labels would be noise for
    // the tail-only documents.
    let frequent_region = (d / 4).max(cfg.n_informative);
    let informative = rng.choose_k(frequent_region, cfg.n_informative);
    let mut w_star = vec![0.0; d];
    for &j in &informative {
        w_star[j] = rng.normal() * 2.0;
    }
    let mut entries = Vec::new();
    let mut y = Vec::with_capacity(cfg.n_docs);
    // idf-like per-feature weights: rarer tokens get higher weight.
    let idf: Vec<f64> = (0..d)
        .map(|j| 1.0 + (d as f64 / (1.0 + j as f64)).ln() * 0.25)
        .collect();
    for i in 0..cfg.n_docs {
        let len = cfg.len_lo + rng.below(cfg.len_hi - cfg.len_lo + 1);
        // Token multiset for this document.
        let mut counts: std::collections::HashMap<usize, f64> = std::collections::HashMap::new();
        for _ in 0..len {
            let tok = rng.zipf(d, cfg.zipf_a);
            *counts.entry(tok).or_insert(0.0) += 1.0;
        }
        let mut margin = 0.0;
        for (&j, &c) in counts.iter() {
            let v = (1.0 + c).ln() * idf[j];
            entries.push((i, j, v));
            margin += v * w_star[j];
        }
        let mut label = if margin >= 0.0 { 1.0 } else { -1.0 };
        if rng.uniform() < cfg.label_noise {
            label = -label;
        }
        y.push(label);
    }
    let mut x = Csr::from_rows(cfg.n_docs, d, entries);
    x.normalize_rows();
    LogRegData { x, y }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::InnerProblem;

    #[test]
    fn deterministic() {
        let cfg = TextConfig {
            n_docs: 50,
            n_features: 200,
            n_informative: 20,
            len_lo: 10,
            len_hi: 30,
            zipf_a: 1.1,
            label_noise: 0.0,
            seed: 3,
        };
        let a = synth_text(&cfg, 7);
        let b = synth_text(&cfg, 7);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let c = synth_text(&cfg, 8);
        assert_ne!(a.y, c.y);
    }

    #[test]
    fn shapes_and_sparsity() {
        let cfg = TextConfig::news20_like();
        let cfg = TextConfig {
            n_docs: 100,
            ..cfg
        };
        let data = synth_text(&cfg, 0);
        assert_eq!(data.x.rows, 100);
        assert_eq!(data.x.cols, 5000);
        assert_eq!(data.y.len(), 100);
        // Sparse: average row has far fewer nnz than d.
        let avg_nnz = data.x.nnz() as f64 / 100.0;
        assert!(avg_nnz < 200.0, "avg nnz {avg_nnz}");
        // Rows are unit norm.
        for r in 0..10 {
            let lo = data.x.indptr[r];
            let hi = data.x.indptr[r + 1];
            let nrm: f64 = data.x.values[lo..hi].iter().map(|v| v * v).sum::<f64>();
            assert!((nrm - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn labels_are_learnable() {
        // A linear model trained on the data must beat chance clearly.
        let cfg = TextConfig {
            n_docs: 300,
            n_features: 500,
            n_informative: 50,
            len_lo: 20,
            len_hi: 60,
            zipf_a: 1.05,
            label_noise: 0.0,
            seed: 5,
        };
        let data = synth_text(&cfg, 1);
        let prob = crate::problems::logreg::LogRegInner { train: data };
        let theta = [(-8.0f64)]; // weak regularization: pure learnability check
        let obj = (500usize, |z: &[f64]| {
            (prob.inner_value(&theta, z).unwrap(), prob.g(&theta, z))
        });
        let res = crate::solvers::minimize::lbfgs_minimize(
            &obj,
            &vec![0.0; 500],
            &crate::solvers::minimize::MinimizeOptions {
                tol: 1e-6,
                max_iters: 500,
                ..Default::default()
            },
            None,
            None,
        );
        assert!(prob.train.error_rate(&res.z) < 0.1);
    }

    #[test]
    fn both_classes_present() {
        let data = synth_text(
            &TextConfig {
                n_docs: 200,
                ..TextConfig::realsim_like()
            },
            0,
        );
        let pos = data.y.iter().filter(|&&v| v > 0.0).count();
        assert!(pos > 20 && pos < 180, "pos={pos}");
    }
}
