//! Small dense correlated-feature dataset (stand-in for the UCI breast
//! cancer dataset, d = 30) used by the Fig. 2-right inversion-quality
//! experiment — small enough that the *exact* `J⁻¹v` is computable with a
//! dense LU solve.
//!
//! Generative model mirroring the real dataset's structure: features are
//! linear mixtures of a handful of latent factors (the real dataset's 30
//! features are mean/se/worst triplets of 10 measurements, hence heavily
//! correlated) plus noise; labels come from a logistic model on the latents.

use crate::linalg::csr::Csr;
use crate::problems::logreg::LogRegData;
use crate::util::rng::Rng;

/// Generate `n` samples with 30 correlated features, labels in {−1, +1}.
pub fn synth_breast(n: usize, seed: u64) -> LogRegData {
    let mut rng = Rng::new(seed ^ 0xB4EA57);
    let d = 30;
    let k = 6; // latent factors
    // Mixing matrix: each feature loads mostly on one factor (plus bleed).
    let mut mixing = vec![vec![0.0; k]; d];
    for (j, row) in mixing.iter_mut().enumerate() {
        let main = j % k;
        for (f, w) in row.iter_mut().enumerate() {
            *w = if f == main {
                1.0 + 0.3 * rng.normal()
            } else {
                0.25 * rng.normal()
            };
        }
    }
    // Label direction in latent space.
    let beta: Vec<f64> = (0..k).map(|_| rng.normal() * 1.5).collect();
    let mut entries = Vec::new();
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let u: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
        let margin: f64 = u.iter().zip(&beta).map(|(a, b)| a * b).sum();
        for (j, row) in mixing.iter().enumerate() {
            let mut v: f64 = row.iter().zip(&u).map(|(a, b)| a * b).sum();
            v += 0.3 * rng.normal();
            entries.push((i, j, v));
        }
        let p = crate::problems::logreg::sigmoid(margin);
        y.push(if rng.uniform() < p { 1.0 } else { -1.0 });
    }
    LogRegData {
        x: Csr::from_rows(n, d, entries),
        y,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_determinism() {
        let a = synth_breast(100, 1);
        let b = synth_breast(100, 1);
        assert_eq!(a.x.rows, 100);
        assert_eq!(a.x.cols, 30);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn features_are_correlated() {
        // Feature j and j+6 share a latent factor: their correlation should
        // be visibly nonzero on average.
        let data = synth_breast(500, 2);
        let dense = data.x.to_dense();
        let col = |j: usize| -> Vec<f64> { (0..500).map(|i| dense[(i, j)]).collect() };
        let c0 = col(0);
        let c6 = col(6);
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        let (m0, m6) = (mean(&c0), mean(&c6));
        let cov: f64 = c0
            .iter()
            .zip(&c6)
            .map(|(a, b)| (a - m0) * (b - m6))
            .sum::<f64>()
            / 500.0;
        let s0 = (c0.iter().map(|a| (a - m0) * (a - m0)).sum::<f64>() / 500.0).sqrt();
        let s6 = (c6.iter().map(|a| (a - m6) * (a - m6)).sum::<f64>() / 500.0).sqrt();
        let corr = cov / (s0 * s6);
        assert!(corr.abs() > 0.2, "corr={corr}");
    }

    #[test]
    fn classes_balanced_enough() {
        let data = synth_breast(400, 3);
        let pos = data.y.iter().filter(|&&v| v > 0.0).count();
        assert!(pos > 80 && pos < 320, "pos={pos}");
    }
}
