//! Synthetic dataset generators — the substitutes for the paper's datasets
//! (see DESIGN.md §3 for the substitution table and its rationale).
//!
//! * [`synth_text`] — sparse text-like binary-classification data standing
//!   in for 20news / real-sim (power-law token frequencies, d ≫ n or n > d).
//! * [`synth_breast`] — small dense correlated-feature dataset standing in
//!   for the UCI breast-cancer set (Fig. 2-right needs exact dense solves).
//! * [`synth_images`] — procedural class-templated images standing in for
//!   CIFAR-10 / ImageNet in the DEQ experiments.
//! * [`split`] — seeded train/val/test splitting (90%/5%/5%, Appendix C).

pub mod split;
pub mod synth_breast;
pub mod synth_images;
pub mod synth_text;

pub use split::split_indices;
pub use synth_breast::synth_breast;
pub use synth_images::{synth_images, ImageDataset};
pub use synth_text::{synth_text, TextConfig};
