//! Bench: Fig. E.1 — HOAG-limited backward + random search baselines
//! (scaled). Full figure: `shine run fig-e1`.

use shine::bilevel::hoag::{hoag_run, HoagOptions};
use shine::bilevel::search::random_search;
use shine::data::split::split_logreg;
use shine::data::synth_text::{synth_text, TextConfig};
use shine::hypergrad::Strategy;
use shine::problems::logreg::{LogRegInner, LogRegOuter};
use shine::util::bench::Bench;
use shine::util::rng::Rng;

fn main() {
    let mut cfg = TextConfig::news20_like();
    cfg.n_docs /= 4;
    cfg.n_features /= 4;
    cfg.n_informative /= 4;
    let data = synth_text(&cfg, 0);
    let mut rng = Rng::new(1);
    let (train, val, test) = split_logreg(&data, &mut rng);
    let prob = LogRegInner { train };
    let outer = LogRegOuter { val, test };
    let mut b = Bench::new("fig-e1 extended baselines (scaled)").with_samples(0, 3);
    for (name, max_iters) in [
        ("hoag-full", usize::MAX),
        ("hoag-limited-5", 5),
        ("hoag-limited-20", 20),
    ] {
        let opts = HoagOptions {
            outer_iters: 15,
            strategy: Strategy::Full {
                tol: 1e-8,
                max_iters,
            },
            ..Default::default()
        };
        let mut finals = Vec::new();
        b.run(name, || {
            let res = hoag_run(&prob, &outer, &[-4.0], &opts);
            finals.push(res.trace.last().unwrap().test_loss);
        });
        println!("  {name}: final test loss {:.4}", finals.last().unwrap());
    }
    let mut srng = Rng::new(7);
    b.run("random-search-8", || {
        random_search(&prob, &outer, -8.0, 0.0, 8, 1e-6, 800, 60.0, &mut srng).best_theta
    });
    b.finish();
}
