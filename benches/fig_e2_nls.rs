//! Bench: Fig. E.2 — regularized NLS HPO (scaled). Full: `shine run fig-e2`.

use shine::bilevel::hoag::{hoag_run, HoagOptions};
use shine::data::split::{logreg_to_nls, split_nls};
use shine::data::synth_text::{synth_text, TextConfig};
use shine::hypergrad::Strategy;
use shine::problems::nls::{NlsInner, NlsOuter};
use shine::qn::lbfgs::OpaConfig;
use shine::util::bench::Bench;
use shine::util::rng::Rng;

fn main() {
    let mut cfg = TextConfig::news20_like();
    cfg.n_docs /= 4;
    cfg.n_features /= 4;
    cfg.n_informative /= 4;
    let data = logreg_to_nls(&synth_text(&cfg, 3));
    let mut rng = Rng::new(4);
    let (train, val, test) = split_nls(&data, &mut rng);
    let prob = NlsInner { train };
    let outer = NlsOuter { val, test };
    let mut b = Bench::new("fig-e2 NLS HPO (scaled)").with_samples(0, 3);
    for (name, strategy, opa) in [
        (
            "hoag",
            Strategy::Full {
                tol: 1e-8,
                max_iters: usize::MAX,
            },
            false,
        ),
        ("shine", Strategy::Shine, false),
        ("shine-opa", Strategy::Shine, true),
        ("jacobian-free", Strategy::JacobianFree, false),
    ] {
        let opts = HoagOptions {
            outer_iters: 15,
            strategy,
            inner_memory: if opa { 60 } else { 30 },
            opa: opa.then_some(OpaConfig { freq: 5, t0: 1.0 }),
            ..Default::default()
        };
        let mut finals = Vec::new();
        b.run(name, || {
            let res = hoag_run(&prob, &outer, &[-4.0], &opts);
            finals.push(res.trace.last().unwrap().test_loss);
        });
        println!("  {name}: final test loss {:.5}", finals.last().unwrap());
    }
    b.finish();
}
