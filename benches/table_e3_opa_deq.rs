//! Bench: Table E.3 — Adjoint Broyden (+OPA) step costs on the tiny variant.
//! Paper-scale accuracies: `shine run table-e3`.

use shine::data::synth_images::synth_images;
use shine::deq::trainer::{BackwardKind, Trainer, TrainerConfig};
use shine::runtime::engine::Engine;
use shine::util::bench::Bench;
use shine::util::rng::Rng;

fn main() {
    let Ok(eng) = Engine::load(&Engine::default_dir()) else {
        eprintln!("SKIP table_e3: artifacts missing");
        return;
    };
    eng.warmup_variant("tiny").unwrap();
    let mut b = Bench::new("table e3 OPA DEQ step (tiny)").with_samples(1, 4);
    for bk in [
        BackwardKind::Original {
            tol: 1e-6,
            max_iters: 1000,
        },
        BackwardKind::JacobianFree,
        BackwardKind::Shine,
        BackwardKind::AdjointBroyden { opa_freq: None },
        BackwardKind::AdjointBroyden { opa_freq: Some(5) },
    ] {
        let cfg = TrainerConfig {
            variant: "tiny".into(),
            backward: bk,
            fwd_max_iters: 15,
            seed: 1,
            ..Default::default()
        };
        let mut tr = Trainer::new(&eng, cfg).unwrap();
        let v = tr.model.v.clone();
        let ds = synth_images(v.batch * 2, v.h, v.w, v.c_in, v.n_classes, 0.4, 2);
        let mut rng = Rng::new(3);
        let idx = ds.epoch_batches(v.batch, &mut rng).remove(0);
        let (x, labels) = ds.batch(&idx);
        b.run(&bk.name(), || tr.train_step(&x, &labels).unwrap().loss);
    }
    b.finish();
}
