//! Bench: Table E.2 — median forward/backward pass per method, tiny variant.
//! Paper-scale rows come from `shine run table-e2` (cifar + imagenet proxies).

use shine::data::synth_images::synth_images;
use shine::deq::trainer::{BackwardKind, Trainer, TrainerConfig};
use shine::runtime::engine::Engine;
use shine::util::bench::Bench;
use shine::util::rng::Rng;
use shine::util::stats;

fn main() {
    let Ok(eng) = Engine::load(&Engine::default_dir()) else {
        eprintln!("SKIP table_e2: artifacts missing (run `make artifacts`)");
        return;
    };
    eng.warmup_variant("tiny").unwrap();
    let mut b = Bench::new("table e2 fwd-bwd timings (tiny)");
    println!(
        "{:<24} {:>10} {:>10}",
        "method", "fwd(ms)", "bwd(ms)"
    );
    for bk in [
        BackwardKind::Original {
            tol: 1e-6,
            max_iters: 1000,
        },
        BackwardKind::JacobianFree,
        BackwardKind::ShineFallback { ratio: 1.3 },
        BackwardKind::ShineRefine { iters: 5 },
        BackwardKind::JacobianFreeRefine { iters: 5 },
        BackwardKind::Original {
            tol: 1e-6,
            max_iters: 5,
        },
    ] {
        let cfg = TrainerConfig {
            variant: "tiny".into(),
            backward: bk,
            fwd_max_iters: 15,
            lr: 0.0,
            seed: 1,
            ..Default::default()
        };
        let mut tr = Trainer::new(&eng, cfg).unwrap();
        let v = tr.model.v.clone();
        let ds = synth_images(v.batch * 4, v.h, v.w, v.c_in, v.n_classes, 0.4, 2);
        let mut rng = Rng::new(3);
        for idx in ds.epoch_batches(v.batch, &mut rng).iter().take(6) {
            let (x, labels) = ds.batch(idx);
            tr.train_step(&x, &labels).unwrap();
        }
        let fwd: Vec<f64> = tr.stats.iter().map(|s| s.fwd_seconds).collect();
        let bwd: Vec<f64> = tr.stats.iter().map(|s| s.bwd_seconds).collect();
        println!(
            "{:<24} {:>10.2} {:>10.2}",
            bk.name(),
            stats::median(&fwd) * 1e3,
            stats::median(&bwd) * 1e3
        );
        b.record(&format!("{} fwd", bk.name()), fwd);
        b.record(&format!("{} bwd", bk.name()), bwd);
    }
    b.finish();
}
