//! Batched DEQ serving throughput: closed-loop load through the
//! scheduler + ServeEngine pipeline at batch widths B ∈ {1, 8, 32}
//! (d = 4096, f32 serving precision), an **open-loop heavy-tailed**
//! continuous-vs-discrete tail-latency comparison at B = 32, a
//! **mixed-precision** B = 32 cell (bf16 U panels, f32 V — the ISSUE 8
//! reduced-precision serving layout) against the homogeneous-f32 row,
//! plus a micro comparison of the one-sweep multi-RHS SHINE backward
//! against per-request panel applies.
//!
//! Emits `BENCH_serve.json` at the repo root with requests/sec,
//! per-request latency and the batched-vs-sequential speedup — the
//! acceptance gates are ≥ 2x throughput at B = 32 over the B = 1
//! baseline, continuous-batching p95 ≤ discrete-batch-formation p95
//! under Pareto arrivals, and ≥ 2x aggregate throughput at 4 scheduler
//! shards over 1 on the sharded many-small-models cell.
//!
//! The **sharded** cells replay one oversaturated open-loop schedule
//! (many small models, each below the kernel parallelism threshold so
//! the shard count is the only parallelism lever) through
//! [`shine::serve::ShardedRouter`] at shards ∈ {1, 2, 4}, plus a
//! mid-run zero-downtime model swap cell (p99 across the cutover), a
//! 90%-hot skewed-traffic cell (work-stealing rebalance), and a **chaos**
//! cell replaying the 2-shard schedule under a seeded
//! [`shine::serve::FaultPlan`] (injected panics, NaN residuals,
//! stragglers) with the circuit breaker armed — the overhead of
//! supervision + typed-outcome accounting under faults, and the p99 cost
//! of a worker respawn.

use shine::linalg::vecops::Bf16;
use shine::qn::low_rank::LowRank;
use shine::qn::workspace::Workspace;
use shine::qn::{InvOp, MemoryPolicy};
use shine::serve::{
    run_open_loop, run_sharded_open_loop, run_sharded_open_loop_with, run_suite, Arrivals,
    BreakerConfig, EngineConfig, FaultPlan, OpenLoopConfig, ServeEngine, ShardedLoadConfig,
    SharedModel, SynthDeq,
};
use shine::solvers::session::SolverSpec;
use shine::util::bench::Bench;
use shine::util::json::Json;
use shine::util::rng::Rng;
use std::sync::Arc;

fn main() {
    let d = 4096usize;
    let block = 64usize;
    let total = 192usize;
    let tol = 1e-5;
    let batch_sizes = [1usize, 8, 32];

    eprintln!(
        "serve_throughput: d={d} block={block} requests/case={total} B={batch_sizes:?} \
         (closed-loop, f32 serving precision)"
    );
    let solver = SolverSpec::picard(1.0).with_tol(tol).with_max_iters(200);
    let rows = run_suite::<f32, f32, f32>(d, block, &batch_sizes, total, solver, 1);

    let mut cases: Vec<Json> = Vec::new();
    let mut accept_speedup = 0.0;
    let mut all_converged = true;
    println!(
        "{:>6} {:>12} {:>10} {:>12} {:>12} {:>10}",
        "B", "req/s", "speedup", "p50 ms", "p95 ms", "iters/req"
    );
    for row in &rows {
        let r = &row.report;
        println!(
            "{:>6} {:>12.1} {:>9.2}x {:>12.3} {:>12.3} {:>10.1}",
            row.b, r.rps, row.speedup_vs_baseline, r.p50_latency_ms, r.p95_latency_ms,
            r.fwd_iters_mean
        );
        if row.b == 32 {
            accept_speedup = row.speedup_vs_baseline;
        }
        all_converged &= r.all_converged;
        let mut c = Json::obj();
        c.set("b", row.b)
            .set("requests", r.requests)
            .set("rps", r.rps)
            .set("speedup_vs_sequential", row.speedup_vs_baseline)
            .set("p50_latency_ms", r.p50_latency_ms)
            .set("p95_latency_ms", r.p95_latency_ms)
            .set("batches", r.batches)
            .set("mean_batch", r.mean_batch)
            .set("fwd_iters_mean", r.fwd_iters_mean)
            .set("all_converged", r.all_converged);
        cases.push(c);
    }

    // Open-loop heavy-tailed arrivals at B = 32: the same Pareto schedule
    // (α = 2.5, offered at 65% of the measured closed-loop capacity)
    // through continuous batching and through discrete batch formation.
    // The tentpole claim is on the tail: admitting into freed columns
    // mid-solve removes the batch-formation wait, so continuous p95 must
    // not exceed discrete p95.
    let bsz = 32usize;
    let rate = 0.65 * rows.last().expect("B=32 row").report.rps;
    let arrivals = Arrivals::Pareto { rate, alpha: 2.5 };
    let model: SynthDeq<f32> = SynthDeq::new(d, block, 1);
    let mut open_reps = Vec::with_capacity(2);
    for continuous in [true, false] {
        let mut engine: ServeEngine<f32> = ServeEngine::new(
            d,
            EngineConfig {
                max_batch: bsz,
                solver,
                calib: SolverSpec::broyden(30).with_tol(tol).with_max_iters(60),
                fallback_ratio: None,
                recalib: None,
                col_budget: if continuous { Some(64) } else { None },
                breaker: None,
            },
        );
        engine.calibrate(
            |z: &[f32], out: &mut [f32]| model.residual_batch(z, 1, out),
            &vec![0.0f32; d],
        );
        let lc = OpenLoopConfig {
            total,
            arrivals,
            max_batch: bsz,
            max_wait: 1e-3,
            continuous,
        };
        let rep = run_open_loop(&mut engine, &model, &lc, 1);
        println!(
            "open-loop {:>10}: p50 {:>8.3} ms  p95 {:>8.3} ms  p99 {:>8.3} ms  \
             width {:>5.2}  evictions {}",
            rep.mode, rep.p50_latency_ms, rep.p95_latency_ms, rep.p99_latency_ms,
            rep.mean_width, rep.evictions
        );
        all_converged &= rep.all_converged;
        open_reps.push(rep);
    }
    let (cont_p95, disc_p95) = (open_reps[0].p95_latency_ms, open_reps[1].p95_latency_ms);

    // Mixed-precision panel cell (ISSUE 8): the same closed-loop B = 32
    // schedule served by an engine whose cached estimate stores U in bf16
    // and keeps V in f32 (`ServeEngine<f32, Bf16, f32>`). Forward cost is
    // identical — the delta isolates the backward sweep's panel-traffic
    // saving at serving scale.
    let mixed_rows = run_suite::<f32, Bf16, f32>(d, block, &[32], total, solver, 1);
    let mixed_rep = &mixed_rows[0].report;
    let f32_b32_rps = rows.last().expect("B=32 row").report.rps;
    println!(
        "mixed-precision B=32: {:>10.1} req/s ({:.2}x f32 panels)  p50 {:>8.3} ms  p95 {:>8.3} ms",
        mixed_rep.rps,
        mixed_rep.rps / f32_b32_rps.max(1e-12),
        mixed_rep.p50_latency_ms,
        mixed_rep.p95_latency_ms
    );
    all_converged &= mixed_rep.all_converged;

    // Sharded scale-out. Geometry chosen so sharding is the only lever:
    // d = 512, B = 8 puts every residual evaluation below the kernel
    // thread-fanout threshold (serial inner loop), and 8 distinct models
    // spread keys across shards. The schedule is oversaturated (burst
    // arrivals), so req/s measures the router's aggregate drain capacity.
    // Per-request results are bit-identical at any shard count (pinned by
    // rust/tests/serve_shard.rs) — these cells measure throughput only.
    let sd = 512usize;
    let sblock = 8usize;
    let smodels = 8usize;
    let stotal = 512usize;
    let sengine = EngineConfig {
        max_batch: 8,
        solver,
        calib: SolverSpec::broyden(30).with_tol(tol).with_max_iters(60),
        fallback_ratio: None,
        recalib: None,
        col_budget: None,
        breaker: None,
    };
    let mk = move |m: u32, v: u32| -> SharedModel<f32> {
        Arc::new(SynthDeq::<f32>::new(
            sd,
            sblock,
            11 + m as u64 + ((v as u64) << 32),
        ))
    };
    let burst = Arrivals::Poisson { rate: 1e6 };
    let mut shard_cells: Vec<Json> = Vec::new();
    let mut shards1_rps = 0.0f64;
    let mut shards4_rps = 0.0f64;
    for shards in [1usize, 2, 4] {
        let lc = ShardedLoadConfig {
            shards,
            models: smodels,
            total: stotal,
            arrivals: burst,
            max_batch: 8,
            max_wait: 1e-3,
            hot_share: None,
            swap_at: None,
            deadline: None,
        };
        let rep = run_sharded_open_loop::<f32, f32, f32>(sengine, &mk, &lc, 7);
        println!(
            "sharded {shards}x: {:>10.1} req/s  p50 {:>8.3} ms  p99 {:>8.3} ms  \
             steals {}",
            rep.rps, rep.p50_latency_ms, rep.p99_latency_ms, rep.steals
        );
        if shards == 1 {
            shards1_rps = rep.rps;
        }
        if shards == 4 {
            shards4_rps = rep.rps;
        }
        all_converged &= rep.all_converged;
        let mut c = Json::obj();
        c.set("shards", shards)
            .set("requests", rep.requests)
            .set("rps", rep.rps)
            .set("p50_latency_ms", rep.p50_latency_ms)
            .set("p99_latency_ms", rep.p99_latency_ms)
            .set("steals", rep.steals)
            .set("calibrations", rep.calibrations)
            .set("all_converged", rep.all_converged);
        shard_cells.push(c);
    }

    // Live-swap cell: model 0 rolls to a new version halfway through the
    // schedule on 4 shards; the p99 across the run is the zero-downtime
    // claim (background calibration must not stall the serving shards).
    let swap_lc = ShardedLoadConfig {
        shards: 4,
        models: smodels,
        total: stotal,
        arrivals: burst,
        max_batch: 8,
        max_wait: 1e-3,
        hot_share: None,
        swap_at: Some(stotal / 2),
        deadline: None,
    };
    let swap_rep = run_sharded_open_loop::<f32, f32, f32>(sengine, &mk, &swap_lc, 7);
    let swap_tel = swap_rep.swap.expect("swap configured");
    println!(
        "sharded swap: p99 {:>8.3} ms across cutover ({} old / {} new, completed {})",
        swap_rep.p99_latency_ms, swap_tel.old_served, swap_tel.new_served, swap_tel.completed
    );
    all_converged &= swap_rep.all_converged;

    // Skewed-traffic cell: 90% of requests hit model 0, so its affinity
    // shard is overloaded and the others idle — whole-queue stealing is
    // what keeps them busy.
    let skew_lc = ShardedLoadConfig {
        shards: 4,
        models: smodels,
        total: stotal,
        arrivals: burst,
        max_batch: 8,
        max_wait: 1e-3,
        hot_share: Some(0.9),
        swap_at: None,
        deadline: None,
    };
    let skew_rep = run_sharded_open_loop::<f32, f32, f32>(sengine, &mk, &skew_lc, 7);
    println!(
        "sharded skew (90% hot): {:>10.1} req/s  p99 {:>8.3} ms  steals {}",
        skew_rep.rps, skew_rep.p99_latency_ms, skew_rep.steals
    );
    all_converged &= skew_rep.all_converged;

    // Chaos cell: the 2-shard schedule under a seeded fault plan (panics,
    // NaN residual columns, stragglers — victims in the first half so the
    // healthy tail closes any opened breaker), with the §3 guard and the
    // per-key circuit breaker armed. Measures the cost of fault tolerance
    // under actual faults: throughput and p99 with a worker respawn in the
    // middle of the run, plus the typed-failure accounting.
    let chaos_engine = EngineConfig {
        fallback_ratio: Some(10.0),
        breaker: Some(BreakerConfig {
            threshold: 2,
            cooldown: 2,
        }),
        ..sengine
    };
    let chaos_plan = FaultPlan::seeded(7 ^ 0xC4A05, stotal / 2, 2, 4, 4);
    let chaos_lc = ShardedLoadConfig {
        shards: 2,
        models: smodels,
        total: stotal,
        arrivals: burst,
        max_batch: 8,
        max_wait: 1e-3,
        hot_share: None,
        swap_at: None,
        deadline: None,
    };
    let chaos_rep = run_sharded_open_loop_with::<f32, f32, f32>(
        chaos_engine,
        &mk,
        &chaos_lc,
        Some(&chaos_plan),
        7,
    );
    println!(
        "sharded chaos (2x, {} faults): {:>10.1} req/s  p99 {:>8.3} ms  \
         {} respawns  {} worker lost  {} model faults  {} shed",
        chaos_plan.len(),
        chaos_rep.rps,
        chaos_rep.p99_latency_ms,
        chaos_rep.respawns,
        chaos_rep.worker_lost,
        chaos_rep.model_faults,
        chaos_rep.shed
    );
    all_converged &= chaos_rep.all_converged;
    let chaos_accounted = chaos_rep.requests + chaos_rep.shed == stotal;

    // Micro view of the serving backward: ONE apply_t_multi sweep for k=32
    // cotangents vs 32 per-request panel applies (m=30 estimate, f32).
    let mut b = Bench::new("serve throughput micro").with_samples(3, 20);
    let m = 30usize;
    let k = 32usize;
    let mut rng = Rng::new(3);
    let mut lr: LowRank<f32> = LowRank::identity(d, m, MemoryPolicy::Freeze);
    for _ in 0..m {
        lr.push(&rng.normal_vec_f32(d, 0.2), &rng.normal_vec_f32(d, 0.2));
    }
    let cots = rng.normal_vec_f32(k * d, 1.0);
    let mut outs = vec![0.0f32; k * d];
    let mut ws: Workspace<f32> = Workspace::new();
    let one_sweep = b
        .run(&format!("backward one-sweep k={k} d={d} m={m}"), || {
            lr.apply_t_multi_into(&cots, &mut outs, &mut ws);
            outs[0]
        })
        .median_ms();
    let per_request = b
        .run(&format!("backward per-request k={k} d={d} m={m}"), || {
            for (xc, oc) in cots.chunks_exact(d).zip(outs.chunks_exact_mut(d)) {
                lr.apply_t_into(xc, oc, &mut ws);
            }
            outs[0]
        })
        .median_ms();
    b.finish();
    let backward_speedup = per_request / one_sweep.max(1e-12);

    let mut j = Json::obj();
    j.set("bench", "serve_throughput")
        .set("d", d)
        .set("block", block)
        .set("requests_per_case", total)
        .set("tol", tol)
        .set("cases", Json::Arr(cases))
        .set(
            "open_loop",
            Json::obj()
                .set("arrivals", "pareto")
                .set("alpha", 2.5)
                .set("offered_rps", rate)
                .set("b", bsz)
                .set("continuous_p50_ms", open_reps[0].p50_latency_ms)
                .set("continuous_p95_ms", cont_p95)
                .set("continuous_p99_ms", open_reps[0].p99_latency_ms)
                .set("continuous_mean_width", open_reps[0].mean_width)
                .set("continuous_evictions", open_reps[0].evictions)
                .set("discrete_p50_ms", open_reps[1].p50_latency_ms)
                .set("discrete_p95_ms", disc_p95)
                .set("discrete_p99_ms", open_reps[1].p99_latency_ms)
                .set("discrete_mean_batch", open_reps[1].mean_width)
                .clone(),
        )
        .set(
            "sharded",
            Json::obj()
                .set("d", sd)
                .set("block", sblock)
                .set("models", smodels)
                .set("requests", stotal)
                .set("max_batch", 8usize)
                .set("cells", Json::Arr(shard_cells))
                .set(
                    "swap",
                    Json::obj()
                        .set("shards", 4usize)
                        .set("swap_at", stotal / 2)
                        .set("rps", swap_rep.rps)
                        .set("p99_latency_ms", swap_rep.p99_latency_ms)
                        .set("old_served", swap_tel.old_served)
                        .set("new_served", swap_tel.new_served)
                        .set("cutover_completed", swap_tel.completed)
                        .clone(),
                )
                .set(
                    "skew",
                    Json::obj()
                        .set("shards", 4usize)
                        .set("hot_share", 0.9)
                        .set("rps", skew_rep.rps)
                        .set("p99_latency_ms", skew_rep.p99_latency_ms)
                        .set("steals", skew_rep.steals)
                        .clone(),
                )
                .set(
                    "chaos",
                    Json::obj()
                        .set("shards", 2usize)
                        .set("faults", chaos_plan.len())
                        .set("rps", chaos_rep.rps)
                        .set("p99_latency_ms", chaos_rep.p99_latency_ms)
                        .set("respawns", chaos_rep.respawns)
                        .set("worker_lost", chaos_rep.worker_lost)
                        .set("model_faults", chaos_rep.model_faults)
                        .set("deadline_exceeded", chaos_rep.deadline_exceeded)
                        .set("retries", chaos_rep.retries)
                        .set("shed", chaos_rep.shed)
                        .set("open_breakers_at_end", chaos_rep.open_breakers)
                        .set("every_request_accounted", chaos_accounted)
                        .clone(),
                )
                .clone(),
        )
        .set(
            "mixed_precision",
            Json::obj()
                .set("b", 32usize)
                .set("layout", "bf16_u_f32_v")
                .set("rps", mixed_rep.rps)
                .set("rps_ratio_vs_f32", mixed_rep.rps / f32_b32_rps.max(1e-12))
                .set("p50_latency_ms", mixed_rep.p50_latency_ms)
                .set("p95_latency_ms", mixed_rep.p95_latency_ms)
                .set("fwd_iters_mean", mixed_rep.fwd_iters_mean)
                .set("all_converged", mixed_rep.all_converged)
                .clone(),
        )
        .set(
            "backward_micro",
            Json::obj()
                .set("k", k)
                .set("m", m)
                .set("one_sweep_ms", one_sweep)
                .set("per_request_ms", per_request)
                .set("one_sweep_speedup", backward_speedup)
                .clone(),
        )
        .set(
            "acceptance",
            Json::obj()
                .set("b", 32usize)
                .set("speedup_vs_sequential", accept_speedup)
                .set("target_speedup", 2.0)
                .set("pass", accept_speedup >= 2.0)
                .set("continuous_p95_ms", cont_p95)
                .set("discrete_p95_ms", disc_p95)
                .set("continuous_beats_discrete_p95", cont_p95 <= disc_p95)
                .set("shards1_reqs_per_s", shards1_rps)
                .set("shards4_reqs_per_s", shards4_rps)
                .set("shard_scaling_target", 2.0)
                .set("shard_scaling_pass", shards4_rps >= 2.0 * shards1_rps)
                .set("swap_p99_ms", swap_rep.p99_latency_ms)
                .set("swap_cutover_completed", swap_tel.completed)
                .set("skew_steals", skew_rep.steals)
                .set("chaos_every_request_accounted", chaos_accounted)
                .set("chaos_respawns", chaos_rep.respawns)
                .set("chaos_open_breakers_at_end", chaos_rep.open_breakers)
                .set("all_converged", all_converged)
                .clone(),
        );
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve.json");
    match shine::util::json::write_file(path, &j) {
        Ok(()) => println!("(wrote {path})"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
    println!(
        "acceptance B=32: {accept_speedup:.2}x batched-vs-sequential throughput \
         (target 2.0x); continuous p95 {cont_p95:.3} ms vs discrete {disc_p95:.3} ms; \
         backward one-sweep {backward_speedup:.2}x vs per-request; \
         shards 4-vs-1 {shards4_rps:.1}/{shards1_rps:.1} req/s (target 2.0x)"
    );
}
