//! Batched DEQ serving throughput: closed-loop load through the
//! scheduler + ServeEngine pipeline at batch widths B ∈ {1, 8, 32}
//! (d = 4096, f32 serving precision), an **open-loop heavy-tailed**
//! continuous-vs-discrete tail-latency comparison at B = 32, plus a micro
//! comparison of the one-sweep multi-RHS SHINE backward against
//! per-request panel applies.
//!
//! Emits `BENCH_serve.json` at the repo root with requests/sec,
//! per-request latency and the batched-vs-sequential speedup — the
//! acceptance gates are ≥ 2x throughput at B = 32 over the B = 1
//! baseline, and continuous-batching p95 ≤ discrete-batch-formation p95
//! under Pareto arrivals.

use shine::qn::low_rank::LowRank;
use shine::qn::workspace::Workspace;
use shine::qn::{InvOp, MemoryPolicy};
use shine::serve::{
    run_open_loop, run_suite, Arrivals, EngineConfig, OpenLoopConfig, ServeEngine, SynthDeq,
};
use shine::solvers::session::SolverSpec;
use shine::util::bench::Bench;
use shine::util::json::Json;
use shine::util::rng::Rng;

fn main() {
    let d = 4096usize;
    let block = 64usize;
    let total = 192usize;
    let tol = 1e-5;
    let batch_sizes = [1usize, 8, 32];

    eprintln!(
        "serve_throughput: d={d} block={block} requests/case={total} B={batch_sizes:?} \
         (closed-loop, f32 serving precision)"
    );
    let solver = SolverSpec::picard(1.0).with_tol(tol).with_max_iters(200);
    let rows = run_suite::<f32>(d, block, &batch_sizes, total, solver, 1);

    let mut cases: Vec<Json> = Vec::new();
    let mut accept_speedup = 0.0;
    let mut all_converged = true;
    println!(
        "{:>6} {:>12} {:>10} {:>12} {:>12} {:>10}",
        "B", "req/s", "speedup", "p50 ms", "p95 ms", "iters/req"
    );
    for row in &rows {
        let r = &row.report;
        println!(
            "{:>6} {:>12.1} {:>9.2}x {:>12.3} {:>12.3} {:>10.1}",
            row.b, r.rps, row.speedup_vs_baseline, r.p50_latency_ms, r.p95_latency_ms,
            r.fwd_iters_mean
        );
        if row.b == 32 {
            accept_speedup = row.speedup_vs_baseline;
        }
        all_converged &= r.all_converged;
        let mut c = Json::obj();
        c.set("b", row.b)
            .set("requests", r.requests)
            .set("rps", r.rps)
            .set("speedup_vs_sequential", row.speedup_vs_baseline)
            .set("p50_latency_ms", r.p50_latency_ms)
            .set("p95_latency_ms", r.p95_latency_ms)
            .set("batches", r.batches)
            .set("mean_batch", r.mean_batch)
            .set("fwd_iters_mean", r.fwd_iters_mean)
            .set("all_converged", r.all_converged);
        cases.push(c);
    }

    // Open-loop heavy-tailed arrivals at B = 32: the same Pareto schedule
    // (α = 2.5, offered at 65% of the measured closed-loop capacity)
    // through continuous batching and through discrete batch formation.
    // The tentpole claim is on the tail: admitting into freed columns
    // mid-solve removes the batch-formation wait, so continuous p95 must
    // not exceed discrete p95.
    let bsz = 32usize;
    let rate = 0.65 * rows.last().expect("B=32 row").report.rps;
    let arrivals = Arrivals::Pareto { rate, alpha: 2.5 };
    let model: SynthDeq<f32> = SynthDeq::new(d, block, 1);
    let mut open_reps = Vec::with_capacity(2);
    for continuous in [true, false] {
        let mut engine: ServeEngine<f32> = ServeEngine::new(
            d,
            EngineConfig {
                max_batch: bsz,
                solver,
                calib: SolverSpec::broyden(30).with_tol(tol).with_max_iters(60),
                fallback_ratio: None,
                recalib: None,
                col_budget: if continuous { Some(64) } else { None },
            },
        );
        engine.calibrate(
            |z: &[f32], out: &mut [f32]| model.residual_batch(z, 1, out),
            &vec![0.0f32; d],
        );
        let lc = OpenLoopConfig {
            total,
            arrivals,
            max_batch: bsz,
            max_wait: 1e-3,
            continuous,
        };
        let rep = run_open_loop(&mut engine, &model, &lc, 1);
        println!(
            "open-loop {:>10}: p50 {:>8.3} ms  p95 {:>8.3} ms  p99 {:>8.3} ms  \
             width {:>5.2}  evictions {}",
            rep.mode, rep.p50_latency_ms, rep.p95_latency_ms, rep.p99_latency_ms,
            rep.mean_width, rep.evictions
        );
        all_converged &= rep.all_converged;
        open_reps.push(rep);
    }
    let (cont_p95, disc_p95) = (open_reps[0].p95_latency_ms, open_reps[1].p95_latency_ms);

    // Micro view of the serving backward: ONE apply_t_multi sweep for k=32
    // cotangents vs 32 per-request panel applies (m=30 estimate, f32).
    let mut b = Bench::new("serve throughput micro").with_samples(3, 20);
    let m = 30usize;
    let k = 32usize;
    let mut rng = Rng::new(3);
    let mut lr: LowRank<f32> = LowRank::identity(d, m, MemoryPolicy::Freeze);
    for _ in 0..m {
        lr.push(&rng.normal_vec_f32(d, 0.2), &rng.normal_vec_f32(d, 0.2));
    }
    let cots = rng.normal_vec_f32(k * d, 1.0);
    let mut outs = vec![0.0f32; k * d];
    let mut ws: Workspace<f32> = Workspace::new();
    let one_sweep = b
        .run(&format!("backward one-sweep k={k} d={d} m={m}"), || {
            lr.apply_t_multi_into(&cots, &mut outs, &mut ws);
            outs[0]
        })
        .median_ms();
    let per_request = b
        .run(&format!("backward per-request k={k} d={d} m={m}"), || {
            for (xc, oc) in cots.chunks_exact(d).zip(outs.chunks_exact_mut(d)) {
                lr.apply_t_into(xc, oc, &mut ws);
            }
            outs[0]
        })
        .median_ms();
    b.finish();
    let backward_speedup = per_request / one_sweep.max(1e-12);

    let mut j = Json::obj();
    j.set("bench", "serve_throughput")
        .set("d", d)
        .set("block", block)
        .set("requests_per_case", total)
        .set("tol", tol)
        .set("cases", Json::Arr(cases))
        .set(
            "open_loop",
            Json::obj()
                .set("arrivals", "pareto")
                .set("alpha", 2.5)
                .set("offered_rps", rate)
                .set("b", bsz)
                .set("continuous_p50_ms", open_reps[0].p50_latency_ms)
                .set("continuous_p95_ms", cont_p95)
                .set("continuous_p99_ms", open_reps[0].p99_latency_ms)
                .set("continuous_mean_width", open_reps[0].mean_width)
                .set("continuous_evictions", open_reps[0].evictions)
                .set("discrete_p50_ms", open_reps[1].p50_latency_ms)
                .set("discrete_p95_ms", disc_p95)
                .set("discrete_p99_ms", open_reps[1].p99_latency_ms)
                .set("discrete_mean_batch", open_reps[1].mean_width)
                .clone(),
        )
        .set(
            "backward_micro",
            Json::obj()
                .set("k", k)
                .set("m", m)
                .set("one_sweep_ms", one_sweep)
                .set("per_request_ms", per_request)
                .set("one_sweep_speedup", backward_speedup)
                .clone(),
        )
        .set(
            "acceptance",
            Json::obj()
                .set("b", 32usize)
                .set("speedup_vs_sequential", accept_speedup)
                .set("target_speedup", 2.0)
                .set("pass", accept_speedup >= 2.0)
                .set("continuous_p95_ms", cont_p95)
                .set("discrete_p95_ms", disc_p95)
                .set("continuous_beats_discrete_p95", cont_p95 <= disc_p95)
                .set("all_converged", all_converged)
                .clone(),
        );
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve.json");
    match shine::util::json::write_file(path, &j) {
        Ok(()) => println!("(wrote {path})"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
    println!(
        "acceptance B=32: {accept_speedup:.2}x batched-vs-sequential throughput \
         (target 2.0x); continuous p95 {cont_p95:.3} ms vs discrete {disc_p95:.3} ms; \
         backward one-sweep {backward_speedup:.2}x vs per-request"
    );
}
