//! Micro-benchmarks of the qN hot loops (the SHINE backward cost itself):
//! FactorPanel low-rank apply across dims and ranks versus the legacy
//! `Vec<Vec<f64>>` baseline, the f32-storage panel path versus the f64 one
//! (the precision-generic `Elem` stack), the bf16 and mixed (bf16 U, f32 V)
//! reduced-precision panel layouts applied to f32 state, Broyden panel
//! updates, multi-RHS cotangent batches, LBFGS two-loop, and
//! native-vs-Pallas-artifact application.
//!
//! Emits `BENCH_qn.json` at the repo root with per-case medians and
//! speedups — the acceptance gates at d=16384, m=30 are
//! `apply_speedup ≥ 2` vs the legacy layout, `f32_apply_speedup_vs_f64
//! ≥ 1.5` (half the panel bytes moved) and `bf16_apply_speedup_vs_f32
//! ≥ 1.3` (half the panel bytes again; sub-2x because the f32 state
//! stream no longer shrinks with the panels).

use shine::linalg::vecops::{axpy, dot, Bf16, Elem};
use shine::qn::broyden::BroydenInverse;
use shine::qn::lbfgs::LbfgsInverse;
use shine::qn::low_rank::LowRank;
use shine::qn::workspace::Workspace;
use shine::qn::{InvOp, MemoryPolicy};
use shine::runtime::engine::Engine;
use shine::util::bench::Bench;
use shine::util::json::Json;
use shine::util::rng::Rng;

/// The seed's storage layout, kept verbatim as the regression baseline:
/// one heap vector per factor, applied factor by factor.
struct LegacyLowRank {
    us: Vec<Vec<f64>>,
    vs: Vec<Vec<f64>>,
}

impl LegacyLowRank {
    fn apply(&self, x: &[f64], out: &mut [f64]) {
        out.copy_from_slice(x);
        for i in 0..self.us.len() {
            let c = dot(&self.vs[i], x);
            if c != 0.0 {
                axpy(c, &self.us[i], out);
            }
        }
    }

    fn apply_t(&self, x: &[f64], out: &mut [f64]) {
        out.copy_from_slice(x);
        for i in 0..self.us.len() {
            let c = dot(&self.us[i], x);
            if c != 0.0 {
                axpy(c, &self.vs[i], out);
            }
        }
    }
}

fn main() {
    let mut rng = Rng::new(1);
    let mut b = Bench::new("micro qn hot loops").with_samples(3, 20);
    let mut cases: Vec<Json> = Vec::new();
    let mut accept_apply = 0.0;
    let mut accept_apply_t = 0.0;
    let mut accept_f32_apply = 0.0;
    let mut accept_f32_apply_t = 0.0;
    let mut accept_bf16_apply = 0.0;
    let mut accept_bf16_apply_t = 0.0;
    let mut accept_mixed_apply = 0.0;
    // Layout-only (single-threaded) signal: the largest case below
    // PAR_MIN_ELEMS, so the panel-vs-legacy comparison excludes threading.
    let mut serial_apply = 0.0;
    let mut serial_apply_t = 0.0;

    for &(d, m) in &[
        (256usize, 10usize),
        (256, 30),
        (4096, 10),
        (4096, 30),
        (16384, 10),
        (16384, 30),
    ] {
        let mut lr = LowRank::identity(d, m, MemoryPolicy::Freeze);
        let mut lr32: LowRank<f32> = LowRank::identity(d, m, MemoryPolicy::Freeze);
        let mut lr16: LowRank<Bf16> = LowRank::identity(d, m, MemoryPolicy::Freeze);
        let mut lrmix: LowRank<Bf16, f32> = LowRank::identity(d, m, MemoryPolicy::Freeze);
        let mut legacy = LegacyLowRank {
            us: Vec::with_capacity(m),
            vs: Vec::with_capacity(m),
        };
        for _ in 0..m {
            let u = rng.normal_vec(d);
            let v = rng.normal_vec(d);
            let u32v: Vec<f32> = u.iter().map(|&a| a as f32).collect();
            let v32v: Vec<f32> = v.iter().map(|&a| a as f32).collect();
            let u16v: Vec<Bf16> = u.iter().map(|&a| Bf16::from_f64(a)).collect();
            let v16v: Vec<Bf16> = v.iter().map(|&a| Bf16::from_f64(a)).collect();
            lr.push(&u, &v);
            lr32.push(&u32v, &v32v);
            lr16.push(&u16v, &v16v);
            lrmix.push(&u16v, &v32v);
            legacy.us.push(u);
            legacy.vs.push(v);
        }
        let x = rng.normal_vec(d);
        let x32: Vec<f32> = x.iter().map(|&a| a as f32).collect();
        let mut out = vec![0.0; d];
        let mut out32 = vec![0.0f32; d];
        let mut ws = Workspace::new();
        let mut ws32: Workspace<f32> = Workspace::new();
        let panel_apply = b
            .run(&format!("panel_apply d={d} m={m}"), || {
                lr.apply_into(&x, &mut out, &mut ws);
                out[0]
            })
            .median_ms();
        let panel_apply_t = b
            .run(&format!("panel_apply_t d={d} m={m}"), || {
                lr.apply_t_into(&x, &mut out, &mut ws);
                out[0]
            })
            .median_ms();
        // f32 storage, f64 accumulation: same sweeps, half the bytes.
        let panel_apply_f32 = b
            .run(&format!("panel_apply_f32 d={d} m={m}"), || {
                lr32.apply_into(&x32, &mut out32, &mut ws32);
                out32[0]
            })
            .median_ms();
        let panel_apply_t_f32 = b
            .run(&format!("panel_apply_t_f32 d={d} m={m}"), || {
                lr32.apply_t_into(&x32, &mut out32, &mut ws32);
                out32[0]
            })
            .median_ms();
        // bf16 panel storage applied to f32 state (the ISSUE 8 serving
        // layout): half the panel bytes of f32 again, widened per element
        // into the same f64 accumulation.
        let panel_apply_bf16 = b
            .run(&format!("panel_apply_bf16 d={d} m={m}"), || {
                lr16.apply_into(&x32, &mut out32, &mut ws32);
                out32[0]
            })
            .median_ms();
        let panel_apply_t_bf16 = b
            .run(&format!("panel_apply_t_bf16 d={d} m={m}"), || {
                lr16.apply_t_into(&x32, &mut out32, &mut ws32);
                out32[0]
            })
            .median_ms();
        // Mixed layout (bf16 U, f32 V): the accuracy-conservative variant —
        // 75% of the homogeneous-f32 panel traffic.
        let panel_apply_mixed = b
            .run(&format!("panel_apply_mixed d={d} m={m}"), || {
                lrmix.apply_into(&x32, &mut out32, &mut ws32);
                out32[0]
            })
            .median_ms();
        let panel_apply_t_mixed = b
            .run(&format!("panel_apply_t_mixed d={d} m={m}"), || {
                lrmix.apply_t_into(&x32, &mut out32, &mut ws32);
                out32[0]
            })
            .median_ms();
        let legacy_apply = b
            .run(&format!("legacy_apply d={d} m={m}"), || {
                legacy.apply(&x, &mut out);
                out[0]
            })
            .median_ms();
        let legacy_apply_t = b
            .run(&format!("legacy_apply_t d={d} m={m}"), || {
                legacy.apply_t(&x, &mut out);
                out[0]
            })
            .median_ms();

        // Multi-RHS: a batch of k cotangents in one panel sweep vs k
        // single-RHS panel applies (both precisions; the multi kernels shard
        // across threads above the size threshold).
        let k = 8usize;
        let xs: Vec<f64> = (0..k * d).map(|_| rng.normal()).collect();
        let xs32: Vec<f32> = xs.iter().map(|&a| a as f32).collect();
        let mut outs = vec![0.0; k * d];
        let mut outs32 = vec![0.0f32; k * d];
        let multi = b
            .run(&format!("panel_apply_multi k={k} d={d} m={m}"), || {
                lr.apply_t_multi(&xs, &mut outs);
                outs[0]
            })
            .median_ms();
        let multi_f32 = b
            .run(&format!("panel_apply_multi_f32 k={k} d={d} m={m}"), || {
                lr32.apply_t_multi(&xs32, &mut outs32);
                outs32[0]
            })
            .median_ms();
        let multi_bf16 = b
            .run(&format!("panel_apply_multi_bf16 k={k} d={d} m={m}"), || {
                lr16.apply_t_multi(&xs32, &mut outs32);
                outs32[0]
            })
            .median_ms();
        let columnwise = b
            .run(&format!("columnwise k={k} d={d} m={m}"), || {
                for (xc, oc) in xs.chunks_exact(d).zip(outs.chunks_exact_mut(d)) {
                    lr.apply_t(xc, oc);
                }
                outs[0]
            })
            .median_ms();

        // Broyden update throughput at steady state: Evict keeps the rank at
        // m, so each timed update is one O(1) eviction + one panel write.
        let mut bro = BroydenInverse::new(d, m, MemoryPolicy::Evict);
        let mut bro32: BroydenInverse<f32> = BroydenInverse::new(d, m, MemoryPolicy::Evict);
        for _ in 0..m {
            let s = rng.normal_vec(d);
            let y = rng.normal_vec(d);
            let s32: Vec<f32> = s.iter().map(|&a| a as f32).collect();
            let y32: Vec<f32> = y.iter().map(|&a| a as f32).collect();
            bro.update_ws(&s, &y, &mut ws);
            bro32.update_ws(&s32, &y32, &mut ws32);
        }
        let s = rng.normal_vec(d);
        let y = rng.normal_vec(d);
        let s32: Vec<f32> = s.iter().map(|&a| a as f32).collect();
        let y32: Vec<f32> = y.iter().map(|&a| a as f32).collect();
        let update = b
            .run(&format!("broyden_update_evict d={d} m={m}"), || {
                bro.update_ws(&s, &y, &mut ws)
            })
            .median_ms();
        let update_f32 = b
            .run(&format!("broyden_update_evict_f32 d={d} m={m}"), || {
                bro32.update_ws(&s32, &y32, &mut ws32)
            })
            .median_ms();

        let apply_speedup = legacy_apply / panel_apply.max(1e-12);
        let apply_t_speedup = legacy_apply_t / panel_apply_t.max(1e-12);
        let f32_apply_speedup = panel_apply / panel_apply_f32.max(1e-12);
        let f32_apply_t_speedup = panel_apply_t / panel_apply_t_f32.max(1e-12);
        let bf16_apply_speedup = panel_apply_f32 / panel_apply_bf16.max(1e-12);
        let bf16_apply_t_speedup = panel_apply_t_f32 / panel_apply_t_bf16.max(1e-12);
        let mixed_apply_speedup = panel_apply_f32 / panel_apply_mixed.max(1e-12);
        let mixed_apply_t_speedup = panel_apply_t_f32 / panel_apply_t_mixed.max(1e-12);
        if d == 16384 && m == 30 {
            accept_apply = apply_speedup;
            accept_apply_t = apply_t_speedup;
            accept_f32_apply = f32_apply_speedup;
            accept_f32_apply_t = f32_apply_t_speedup;
            accept_bf16_apply = bf16_apply_speedup;
            accept_bf16_apply_t = bf16_apply_t_speedup;
            accept_mixed_apply = mixed_apply_speedup;
        }
        if d == 4096 && m == 30 {
            serial_apply = apply_speedup;
            serial_apply_t = apply_t_speedup;
        }
        let mut c = Json::obj();
        c.set("d", d)
            .set("m", m)
            .set("panel_apply_ms", panel_apply)
            .set("panel_apply_t_ms", panel_apply_t)
            .set("panel_apply_f32_ms", panel_apply_f32)
            .set("panel_apply_t_f32_ms", panel_apply_t_f32)
            .set("panel_apply_bf16_ms", panel_apply_bf16)
            .set("panel_apply_t_bf16_ms", panel_apply_t_bf16)
            .set("panel_apply_mixed_ms", panel_apply_mixed)
            .set("panel_apply_t_mixed_ms", panel_apply_t_mixed)
            .set("legacy_apply_ms", legacy_apply)
            .set("legacy_apply_t_ms", legacy_apply_t)
            .set("apply_speedup", apply_speedup)
            .set("apply_t_speedup", apply_t_speedup)
            .set("f32_apply_speedup_vs_f64", f32_apply_speedup)
            .set("f32_apply_t_speedup_vs_f64", f32_apply_t_speedup)
            .set("bf16_apply_speedup_vs_f32", bf16_apply_speedup)
            .set("bf16_apply_t_speedup_vs_f32", bf16_apply_t_speedup)
            .set("mixed_apply_speedup_vs_f32", mixed_apply_speedup)
            .set("mixed_apply_t_speedup_vs_f32", mixed_apply_t_speedup)
            .set("apply_gflops", 4.0 * (m * d) as f64 / (panel_apply * 1e6).max(1e-12))
            .set("multi_rhs_k", k)
            .set("apply_t_multi_ms", multi)
            .set("apply_t_multi_f32_ms", multi_f32)
            .set("apply_t_multi_bf16_ms", multi_bf16)
            .set("apply_t_columnwise_ms", columnwise)
            .set("multi_speedup", columnwise / multi.max(1e-12))
            .set("broyden_update_ms", update)
            .set("broyden_update_f32_ms", update_f32);
        cases.push(c);
    }

    // LBFGS two-loop at DEQ-ish scale.
    let d = 65536;
    let mut lb = LbfgsInverse::new(d, 30);
    for _ in 0..30 {
        let s = rng.normal_vec(d);
        let mut y = rng.normal_vec(d);
        if dot(&s, &y) < 0.0 {
            for v in y.iter_mut() {
                *v = -*v;
            }
        }
        lb.update(&s, &y);
    }
    let x = rng.normal_vec(d);
    let mut out = vec![0.0; d];
    let mut ws = Workspace::new();
    b.run("lbfgs_two_loop d=65536 m=30", || {
        lb.apply_into(&x, &mut out, &mut ws);
        out[0]
    });

    // Native vs Pallas-artifact low-rank apply (the L1 kernel), if available.
    if let Ok(eng) = Engine::load(&Engine::default_dir()) {
        if let Ok(model) = shine::deq::model::DeqModel::new(&eng, "tiny") {
            let d = model.v.fixed_point_dim;
            let mut rng = Rng::new(2);
            let v32 = rng.normal_vec_f32(d, 1.0);
            let us = rng.normal_vec_f32(30 * d, 0.2);
            let vs = rng.normal_vec_f32(30 * d, 0.2);
            b.run(&format!("lowrank artifact (pallas) d={d}"), || {
                model.lowrank_apply(&v32, &us, &vs).unwrap().len()
            });
            // Native f32 panels — the exact layout the DEQ trainer now runs.
            let mut lrn: LowRank<f32> = LowRank::identity(d, 30, MemoryPolicy::Freeze);
            for i in 0..30 {
                lrn.push(&us[i * d..(i + 1) * d], &vs[i * d..(i + 1) * d]);
            }
            let mut out32 = vec![0.0f32; d];
            b.run(&format!("lowrank native f32 d={d}"), || {
                lrn.apply(&v32, &mut out32);
                out32[0]
            });
        }
    }
    b.finish();

    // Machine-readable perf trajectory: BENCH_qn.json at the repo root.
    let mut j = Json::obj();
    j.set("bench", "micro_qn")
        .set("cases", Json::Arr(cases))
        .set(
            "acceptance",
            Json::obj()
                .set("d", 16384usize)
                .set("m", 30usize)
                .set("apply_speedup_vs_legacy", accept_apply)
                .set("apply_t_speedup_vs_legacy", accept_apply_t)
                // The acceptance cell runs the thread-parallel panel path by
                // design; these layout-only numbers (d=4096, m=30 — largest
                // serial cell) separate contiguity wins from threading wins
                // so a serial-kernel regression stays visible.
                .set("serial_cell_apply_speedup_vs_legacy", serial_apply)
                .set("serial_cell_apply_t_speedup_vs_legacy", serial_apply_t)
                .set("target_speedup", 2.0)
                .set("pass", accept_apply >= 2.0 && accept_apply_t >= 2.0)
                // f32-panel gate: the half-traffic path must move ≥1.5x
                // faster than the f64 panel apply at MDEQ-ish scale.
                .set("f32_apply_speedup_vs_f64", accept_f32_apply)
                .set("f32_apply_t_speedup_vs_f64", accept_f32_apply_t)
                .set("f32_target_speedup", 1.5)
                .set("f32_pass", accept_f32_apply >= 1.5)
                // bf16-panel gate (ISSUE 8): halving the bytes again must
                // buy ≥1.3x over the f32 panel apply at the same memory-bound
                // cell (sub-2x because the f32 state/accumulation stream no
                // longer shrinks with the panels).
                .set("bf16_apply_speedup_vs_f32", accept_bf16_apply)
                .set("bf16_apply_t_speedup_vs_f32", accept_bf16_apply_t)
                .set("mixed_apply_speedup_vs_f32", accept_mixed_apply)
                .set("bf16_target_speedup", 1.3)
                .set("bf16_pass", accept_bf16_apply >= 1.3)
                .clone(),
        );
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_qn.json");
    match shine::util::json::write_file(path, &j) {
        Ok(()) => println!("(wrote {path})"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
    println!(
        "acceptance d=16384 m=30: apply {accept_apply:.2}x, apply_t {accept_apply_t:.2}x vs \
         legacy; f32 panel {accept_f32_apply:.2}x / {accept_f32_apply_t:.2}x vs f64 panel; \
         bf16 panel {accept_bf16_apply:.2}x, mixed {accept_mixed_apply:.2}x vs f32 panel"
    );
}
