//! Micro-benchmarks of the qN hot loops (the SHINE backward cost itself):
//! low-rank apply across dims and ranks, Broyden updates, LBFGS two-loop,
//! and native-vs-Pallas-artifact low-rank application.

use shine::qn::broyden::BroydenInverse;
use shine::qn::lbfgs::LbfgsInverse;
use shine::qn::low_rank::LowRank;
use shine::qn::{InvOp, MemoryPolicy};
use shine::runtime::engine::Engine;
use shine::util::bench::Bench;
use shine::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(1);
    let mut b = Bench::new("micro qn hot loops").with_samples(3, 30);
    for &(d, m) in &[(4096usize, 30usize), (65536, 30), (184320, 30)] {
        let mut lr = LowRank::identity(d, m, MemoryPolicy::Freeze);
        for _ in 0..m {
            lr.push(rng.normal_vec(d), rng.normal_vec(d));
        }
        let x = rng.normal_vec(d);
        let mut out = vec![0.0; d];
        b.run(&format!("lowrank_apply d={d} m={m}"), || {
            lr.apply(&x, &mut out);
            out[0]
        });
        b.run(&format!("lowrank_apply_t d={d} m={m}"), || {
            lr.apply_t(&x, &mut out);
            out[0]
        });
    }
    // Broyden update cost (the forward-pass bookkeeping per iteration).
    let d = 65536;
    let mut bro = BroydenInverse::new(d, 64, MemoryPolicy::Freeze);
    for _ in 0..30 {
        bro.update(&rng.normal_vec(d), &rng.normal_vec(d));
    }
    let s = rng.normal_vec(d);
    let y = rng.normal_vec(d);
    b.run("broyden_update d=65536 rank=30", || {
        let mut b2 = bro.clone();
        b2.update(&s, &y)
    });
    // LBFGS two-loop.
    let mut lb = LbfgsInverse::new(d, 30);
    for _ in 0..30 {
        let s = rng.normal_vec(d);
        let mut y = rng.normal_vec(d);
        if shine::linalg::vecops::dot(&s, &y) < 0.0 {
            for v in y.iter_mut() {
                *v = -*v;
            }
        }
        lb.update(&s, &y);
    }
    let x = rng.normal_vec(d);
    let mut out = vec![0.0; d];
    b.run("lbfgs_two_loop d=65536 m=30", || {
        lb.apply(&x, &mut out);
        out[0]
    });
    // Native vs Pallas-artifact low-rank apply (the L1 kernel), if available.
    if let Ok(eng) = Engine::load(&Engine::default_dir()) {
        if let Ok(model) = shine::deq::model::DeqModel::new(&eng, "tiny") {
            let d = model.v.fixed_point_dim;
            let mut rng = Rng::new(2);
            let v32 = rng.normal_vec_f32(d, 1.0);
            let us = rng.normal_vec_f32(30 * d, 0.2);
            let vs = rng.normal_vec_f32(30 * d, 0.2);
            b.run(&format!("lowrank artifact (pallas) d={d}"), || {
                model.lowrank_apply(&v32, &us, &vs).unwrap().len()
            });
            let mut lrn = LowRank::identity(d, 30, MemoryPolicy::Freeze);
            for i in 0..30 {
                lrn.push(
                    us[i * d..(i + 1) * d].iter().map(|&x| x as f64).collect(),
                    vs[i * d..(i + 1) * d].iter().map(|&x| x as f64).collect(),
                );
            }
            let v64: Vec<f64> = v32.iter().map(|&x| x as f64).collect();
            let mut out = vec![0.0; d];
            b.run(&format!("lowrank native d={d}"), || {
                lrn.apply(&v64, &mut out);
                out[0]
            });
        }
    }
    b.finish();
}
