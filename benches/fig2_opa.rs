//! Bench: Fig. 2-left — OPA vs vanilla SHINE vs HOAG on the 20news-like
//! problem (scaled). Full figure: `shine run fig2-left`.

use shine::bilevel::hoag::{hoag_run, HoagOptions};
use shine::data::split::split_logreg;
use shine::data::synth_text::{synth_text, TextConfig};
use shine::hypergrad::Strategy;
use shine::problems::logreg::{LogRegInner, LogRegOuter};
use shine::qn::lbfgs::OpaConfig;
use shine::util::bench::Bench;
use shine::util::rng::Rng;

fn main() {
    let mut cfg = TextConfig::news20_like();
    cfg.n_docs /= 4;
    cfg.n_features /= 4;
    cfg.n_informative /= 4;
    let data = synth_text(&cfg, 0);
    let mut rng = Rng::new(1);
    let (train, val, test) = split_logreg(&data, &mut rng);
    let prob = LogRegInner { train };
    let outer = LogRegOuter { val, test };
    let mut b = Bench::new("fig2-left OPA bilevel (scaled)").with_samples(0, 3);
    for (name, opa) in [("hoag", None), ("shine", None), ("shine-opa", Some(5usize))] {
        let full = name == "hoag";
        let opts = HoagOptions {
            outer_iters: 15,
            strategy: if full {
                Strategy::Full {
                    tol: 1e-8,
                    max_iters: usize::MAX,
                }
            } else {
                Strategy::Shine
            },
            inner_memory: if opa.is_some() { 60 } else { 30 },
            opa: opa.map(|freq| OpaConfig { freq, t0: 1.0 }),
            ..Default::default()
        };
        let mut finals = Vec::new();
        b.run(name, || {
            let res = hoag_run(&prob, &outer, &[-4.0], &opts);
            finals.push(res.trace.last().unwrap().test_loss);
        });
        println!("  {name}: final test loss {:.4}", finals.last().unwrap());
    }
    b.finish();
}
