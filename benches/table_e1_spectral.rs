//! Bench: Table E.1 — nonlinear spectral radius probe (tiny variant).
//! Paper-scale: `shine run table-e1`.

use shine::data::synth_images::synth_images;
use shine::deq::trainer::{BackwardKind, Trainer, TrainerConfig};
use shine::power::power_method;
use shine::runtime::engine::Engine;
use shine::util::bench::Bench;
use shine::util::rng::Rng;

fn main() {
    let Ok(eng) = Engine::load(&Engine::default_dir()) else {
        eprintln!("SKIP table_e1: artifacts missing");
        return;
    };
    eng.warmup_variant("tiny").unwrap();
    let mut b = Bench::new("table e1 spectral radius (tiny)").with_samples(0, 2);
    let cfg = TrainerConfig {
        variant: "tiny".into(),
        backward: BackwardKind::Shine,
        fwd_max_iters: 15,
        seed: 1,
        ..Default::default()
    };
    let tr = Trainer::new(&eng, cfg).unwrap();
    let v = tr.model.v.clone();
    let ds = synth_images(v.batch, v.h, v.w, v.c_in, v.n_classes, 0.4, 2);
    let mut rng = Rng::new(3);
    let idx = ds.epoch_batches(v.batch, &mut rng).remove(0);
    let (x, _) = ds.batch(&idx);
    let u = tr.model.inject(&tr.params, &x).unwrap();
    let fwd = tr.forward_solve(&u).unwrap();
    let mut radius = 0.0;
    b.run("power-method-20-iters", || {
        let res = power_method(
            |vv: &[f32], out: &mut [f32]| match tr.model.f_jvp(&tr.params, &fwd.z, &u, vv) {
                Ok(t) => out.copy_from_slice(&t),
                Err(_) => out.copy_from_slice(vv),
            },
            fwd.z.len(),
            20,
            &mut rng,
        );
        radius = res.radius;
        radius
    });
    println!("  untrained-tiny spectral radius: {radius:.2} (paper: 194-234, >> 1)");
    b.finish();
}
